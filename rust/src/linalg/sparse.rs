//! Sparse (CSC) feature-matrix backend.
//!
//! The paper's motivation (§1) is that at MNIST/SVHN scale "we may not even
//! be able to load the data matrix into main memory"; image/stroke data is
//! naturally sparse. [`CscMatrix`] implements the full [`DesignMatrix`]
//! contract, so every screening rule, every solver, the path drivers and
//! the service run on sparse data unchanged — a CD epoch on a reduced
//! problem costs O(Σ_{j∈cols} nnz(xⱼ)) instead of O(N·|cols|).

use super::{DenseMatrix, DesignMatrix};

/// Compressed-sparse-column matrix (f64 values).
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    n_rows: usize,
    n_cols: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<u32>,
    values: Vec<f64>,
}

impl CscMatrix {
    /// Build from raw CSC parts — the constructor for callers that stream
    /// sparse data in directly (libsvm readers, sparse generators) without
    /// ever materializing a dense matrix. Row indices must be strictly
    /// increasing within each column.
    pub fn from_parts(
        n_rows: usize,
        n_cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<u32>,
        values: Vec<f64>,
    ) -> CscMatrix {
        assert!(n_rows <= u32::MAX as usize);
        assert_eq!(col_ptr.len(), n_cols + 1, "col_ptr must have n_cols+1 entries");
        assert_eq!(col_ptr[0], 0);
        assert_eq!(*col_ptr.last().unwrap(), values.len());
        assert_eq!(row_idx.len(), values.len());
        // validate the whole pointer array before slicing any column, so a
        // bad col_ptr reports its own diagnostic rather than a raw
        // out-of-bounds panic below
        for j in 0..n_cols {
            assert!(col_ptr[j] <= col_ptr[j + 1], "col_ptr must be nondecreasing at {j}");
            assert!(col_ptr[j + 1] <= values.len(), "col_ptr out of range at {j}");
        }
        for j in 0..n_cols {
            let col = &row_idx[col_ptr[j]..col_ptr[j + 1]];
            for w in col.windows(2) {
                assert!(w[0] < w[1], "row indices must be strictly increasing in column {j}");
            }
            if let Some(&last) = col.last() {
                assert!((last as usize) < n_rows, "row index out of range in column {j}");
            }
        }
        CscMatrix { n_rows, n_cols, col_ptr, row_idx, values }
    }

    /// Build from a dense matrix, dropping exact zeros.
    pub fn from_dense(x: &DenseMatrix) -> CscMatrix {
        let (n, p) = (x.n_rows(), x.n_cols());
        assert!(n <= u32::MAX as usize);
        let mut col_ptr = Vec::with_capacity(p + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for j in 0..p {
            for (i, &v) in x.col(j).iter().enumerate() {
                if v != 0.0 {
                    row_idx.push(i as u32);
                    values.push(v);
                }
            }
            col_ptr.push(values.len());
        }
        CscMatrix { n_rows: n, n_cols: p, col_ptr, row_idx, values }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }
    /// Stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }
    /// Fill fraction.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n_rows * self.n_cols).max(1) as f64
    }

    /// (row indices, values) of column j.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.col_ptr[j], self.col_ptr[j + 1]);
        (&self.row_idx[a..b], &self.values[a..b])
    }

    /// Single element (binary search over the column — I/O and tests, not
    /// hot loops).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (idx, vals) = self.col(j);
        match idx.binary_search(&(i as u32)) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// Scale every column to unit ℓ2 norm in place (zero columns left
    /// untouched). Returns the original norms — the sparse counterpart of
    /// `DenseMatrix::normalize_columns`.
    pub fn normalize_columns(&mut self) -> Vec<f64> {
        let mut norms = Vec::with_capacity(self.n_cols);
        for j in 0..self.n_cols {
            let (a, b) = (self.col_ptr[j], self.col_ptr[j + 1]);
            let nj = self.values[a..b].iter().map(|v| v * v).sum::<f64>().sqrt();
            norms.push(nj);
            if nj > 0.0 {
                for v in self.values[a..b].iter_mut() {
                    *v /= nj;
                }
            }
        }
        norms
    }

    /// Sparse dot `xⱼᵀw`.
    #[inline]
    pub fn col_dot(&self, j: usize, w: &[f64]) -> f64 {
        let (idx, vals) = self.col(j);
        let mut s = 0.0;
        for (i, v) in idx.iter().zip(vals.iter()) {
            s += w[*i as usize] * v;
        }
        s
    }

    /// `out[j] = xⱼᵀw` for all j — the sparse screening sweep, O(nnz).
    pub fn gemv_t(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        for j in 0..self.n_cols {
            out[j] = self.col_dot(j, w);
        }
    }

    /// `out += a·xⱼ` (scatter-axpy).
    #[inline]
    pub fn col_axpy(&self, j: usize, a: f64, out: &mut [f64]) {
        let (idx, vals) = self.col(j);
        for (i, v) in idx.iter().zip(vals.iter()) {
            out[*i as usize] += a * v;
        }
    }

    /// ℓ2 norm per column.
    pub fn col_norms(&self) -> Vec<f64> {
        (0..self.n_cols)
            .map(|j| {
                let (_, vals) = self.col(j);
                vals.iter().map(|v| v * v).sum::<f64>().sqrt()
            })
            .collect()
    }

    /// Sparse-sparse dot `xᵢᵀxⱼ` by merge-join on the sorted row indices.
    pub fn col_dot_col(&self, i: usize, j: usize) -> f64 {
        let (ai, av) = self.col(i);
        let (bi, bv) = self.col(j);
        let (mut a, mut b) = (0usize, 0usize);
        let mut s = 0.0;
        while a < ai.len() && b < bi.len() {
            match ai[a].cmp(&bi[b]) {
                std::cmp::Ordering::Less => a += 1,
                std::cmp::Ordering::Greater => b += 1,
                std::cmp::Ordering::Equal => {
                    s += av[a] * bv[b];
                    a += 1;
                    b += 1;
                }
            }
        }
        s
    }

    /// Densify (tests / small problems).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut x = DenseMatrix::zeros(self.n_rows, self.n_cols);
        for j in 0..self.n_cols {
            let (idx, vals) = self.col(j);
            let c = x.col_mut(j);
            for (i, v) in idx.iter().zip(vals.iter()) {
                c[*i as usize] = *v;
            }
        }
        x
    }
}

impl DesignMatrix for CscMatrix {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        self.gemv_t(w, out);
    }

    fn col_dot_w(&self, j: usize, w: &[f64]) -> f64 {
        self.col_dot(j, w)
    }

    fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]) {
        self.col_axpy(j, a, out);
    }

    fn col_sq_norm(&self, j: usize) -> f64 {
        let (_, vals) = self.col(j);
        vals.iter().map(|v| v * v).sum()
    }

    fn col_dot_col(&self, i: usize, j: usize) -> f64 {
        CscMatrix::col_dot_col(self, i, j)
    }

    fn col_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.n_rows);
        out.fill(0.0);
        let (idx, vals) = self.col(j);
        for (i, v) in idx.iter().zip(vals.iter()) {
            out[*i as usize] = *v;
        }
    }

    fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]) {
        assert_eq!(rows.len(), out.len());
        // row indices are sorted within a column — binary search per row
        let (idx, vals) = self.col(j);
        for (o, &r) in out.iter_mut().zip(rows.iter()) {
            *o = match idx.binary_search(&(r as u32)) {
                Ok(k) => vals[k],
                Err(_) => 0.0,
            };
        }
    }

    fn nnz(&self) -> usize {
        self.values.len()
    }

    fn col_norms(&self) -> Vec<f64> {
        CscMatrix::col_norms(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::{cd::CdSolver, dual, LassoSolver, SolveOptions};
    use crate::util::{prop, rng::Rng};

    fn sparse_problem(n: usize, p: usize, density: f64, seed: u64) -> (DenseMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = DenseMatrix::zeros(n, p);
        for j in 0..p {
            let c = x.col_mut(j);
            for v in c.iter_mut() {
                if rng.f64() < density {
                    *v = rng.normal();
                }
            }
        }
        let beta = synthetic::sparse_ground_truth(p, p / 8 + 1, &mut rng);
        let y = synthetic::linear_response(&x, &beta, 0.1, &mut rng);
        (x, y)
    }

    #[test]
    fn roundtrip_dense_csc_dense() {
        let (x, _) = sparse_problem(20, 30, 0.2, 1);
        let csc = CscMatrix::from_dense(&x);
        assert_eq!(csc.to_dense(), x);
        assert!(csc.density() < 0.3);
    }

    #[test]
    fn from_parts_matches_from_dense() {
        let (x, _) = sparse_problem(15, 10, 0.3, 2);
        let via_dense = CscMatrix::from_dense(&x);
        let mut col_ptr = vec![0usize];
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        for j in 0..10 {
            for (i, &v) in x.col(j).iter().enumerate() {
                if v != 0.0 {
                    row_idx.push(i as u32);
                    values.push(v);
                }
            }
            col_ptr.push(values.len());
        }
        let direct = CscMatrix::from_parts(15, 10, col_ptr, row_idx, values);
        assert_eq!(direct, via_dense);
    }

    #[test]
    #[should_panic]
    fn from_parts_rejects_unsorted_rows() {
        CscMatrix::from_parts(4, 1, vec![0, 2], vec![2, 1], vec![1.0, 2.0]);
    }

    #[test]
    fn sweep_matches_dense_randomized() {
        prop::check("csc gemv_t == dense gemv_t", 0xC5C, 20, |rng| {
            let n = 1 + rng.usize(30);
            let p = 1 + rng.usize(40);
            let (x, _) = sparse_problem(n, p, rng.uniform(0.05, 0.5), rng.next_u64());
            let csc = CscMatrix::from_dense(&x);
            let mut w = vec![0.0; n];
            rng.fill_normal(&mut w);
            let mut a = vec![0.0; p];
            let mut b = vec![0.0; p];
            csc.gemv_t(&w, &mut a);
            x.gemv_t(&w, &mut b);
            for j in 0..p {
                assert!((a[j] - b[j]).abs() < 1e-10 * (1.0 + b[j].abs()));
            }
        });
    }

    #[test]
    fn col_norms_match_dense() {
        let (x, _) = sparse_problem(25, 35, 0.3, 3);
        let csc = CscMatrix::from_dense(&x);
        for (a, b) in csc.col_norms().iter().zip(x.col_norms().iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn col_dot_col_matches_dense_gram() {
        prop::check("csc gram == dense gram", 0xC5D, 15, |rng| {
            let n = 1 + rng.usize(20);
            let p = 2 + rng.usize(15);
            let (x, _) = sparse_problem(n, p, rng.uniform(0.1, 0.7), rng.next_u64());
            let csc = CscMatrix::from_dense(&x);
            let i = rng.usize(p);
            let j = rng.usize(p);
            let dense = crate::linalg::dot(x.col(i), x.col(j));
            assert!((csc.col_dot_col(i, j) - dense).abs() < 1e-10 * (1.0 + dense.abs()));
        });
    }

    /// The CD solver through the `DesignMatrix` trait is the sparse solver:
    /// its epoch cost on CSC is O(nnz of the surviving columns), and its
    /// answers match the dense backend to gap tolerance.
    #[test]
    fn cd_on_csc_matches_cd_on_dense() {
        let (x, y) = sparse_problem(40, 120, 0.15, 4);
        let csc = CscMatrix::from_dense(&x);
        let lam = 0.3 * dual::lambda_max(&x, &y);
        let cols: Vec<usize> = (0..120).collect();
        let opts = SolveOptions { tol_gap: 1e-11, ..Default::default() };
        let sp = CdSolver.solve(&csc, &y, &cols, lam, None, &opts);
        let de = CdSolver.solve(&x, &y, &cols, lam, None, &opts);
        let o_sp = dual::primal_objective(&csc, &y, &cols, &sp.beta, lam);
        let o_de = dual::primal_objective(&x, &y, &cols, &de.beta, lam);
        assert!((o_sp - o_de).abs() < 1e-6 * (1.0 + o_de.abs()));
        assert!(sp.gap < 1e-7);
    }

    #[test]
    fn screening_rules_run_on_sparse_backend() {
        // EDPP on a context built over the CSC backend must equal dense
        use crate::screening::{edpp::EdppRule, ScreenContext, ScreeningRule, StepInput};
        let (x, y) = sparse_problem(30, 80, 0.2, 5);
        let csc = CscMatrix::from_dense(&x);
        let dense_ctx = ScreenContext::new(&x, &y);
        let sparse_ctx = ScreenContext::new(&csc, &y);
        let theta: Vec<f64> = y.iter().map(|v| v / dense_ctx.lam_max).collect();
        let step = StepInput {
            lam_prev: dense_ctx.lam_max,
            lam: 0.5 * dense_ctx.lam_max,
            theta_prev: &theta,
        };
        let mut keep_d = vec![true; 80];
        let mut keep_s = vec![true; 80];
        EdppRule.screen(&dense_ctx, &step, &mut keep_d);
        EdppRule.screen(&sparse_ctx, &step, &mut keep_s);
        assert_eq!(keep_d, keep_s);
    }

    #[test]
    fn empty_and_zero_column_edge_cases() {
        let x = DenseMatrix::zeros(5, 3);
        let csc = CscMatrix::from_dense(&x);
        assert_eq!(csc.nnz(), 0);
        let mut out = vec![1.0; 3];
        csc.gemv_t(&[1.0; 5], &mut out);
        assert_eq!(out, vec![0.0; 3]);
        let res = CdSolver.solve(
            &csc,
            &[1.0; 5],
            &[0, 1, 2],
            0.5,
            None,
            &SolveOptions::default(),
        );
        assert!(res.beta.iter().all(|b| *b == 0.0));
    }
}
