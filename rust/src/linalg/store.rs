//! Runtime-chosen matrix backend: [`DesignStore`] is the owned,
//! enum-dispatched counterpart of `&dyn DesignMatrix`.
//!
//! `data::Dataset` carries its feature matrix as a `DesignStore`, so a
//! dataset loaded from sparse LIBSVM input stays CSC end-to-end, a dataset
//! opened from an on-disk shard stays out-of-core, and a shard-set manifest
//! opens as the row-sharded pool-parallel backend — nothing densifies on
//! the way from I/O to screening (the bug this type fixes: `read_libsvm`
//! used to materialize a `DenseMatrix` before the backend choice ever
//! happened). The store implements [`DesignMatrix`] itself by delegation,
//! so `&ds.x` keeps coercing to `&dyn DesignMatrix` at every
//! rule/solver/path call site regardless of the variant inside.
//!
//! Dense-only accessors (`dense`, `dense_mut`, `normalize_columns`) return
//! line-actionable `anyhow` errors on backends that cannot satisfy them —
//! a CLI path must never abort the process because the user picked an
//! out-of-core input; materializing is always available explicitly via
//! [`DesignStore::to_dense`] / [`DesignStore::into_dense`].

use anyhow::{bail, Result};

use super::{CscMatrix, DenseMatrix, DesignMatrix, MmapCscMatrix, ShardSetMatrix};

/// Owned feature-matrix backend chosen at load time (or by `--matrix`).
#[derive(Clone, Debug)]
pub enum DesignStore {
    Dense(DenseMatrix),
    Csc(CscMatrix),
    Mmap(MmapCscMatrix),
    Sharded(ShardSetMatrix),
}

impl DesignStore {
    /// Backend tag for reports (`dense` / `csc` / `mmap` / `sharded`).
    pub fn backend_name(&self) -> &'static str {
        match self {
            DesignStore::Dense(_) => "dense",
            DesignStore::Csc(_) => "csc",
            DesignStore::Mmap(_) => "mmap",
            DesignStore::Sharded(_) => "sharded",
        }
    }

    /// Borrow as the matrix-free trait object.
    pub fn as_design(&self) -> &dyn DesignMatrix {
        match self {
            DesignStore::Dense(x) => x,
            DesignStore::Csc(x) => x,
            DesignStore::Mmap(x) => x,
            DesignStore::Sharded(x) => x,
        }
    }

    /// Box the inner backend for `ScreeningService::spawn_boxed`.
    pub fn into_boxed(self) -> Box<dyn DesignMatrix + Send> {
        match self {
            DesignStore::Dense(x) => Box::new(x),
            DesignStore::Csc(x) => Box::new(x),
            DesignStore::Mmap(x) => Box::new(x),
            DesignStore::Sharded(x) => Box::new(x),
        }
    }

    pub fn n_rows(&self) -> usize {
        self.as_design().n_rows()
    }
    pub fn n_cols(&self) -> usize {
        self.as_design().n_cols()
    }
    /// Stored entries (dense: N·p; sparse backends: true non-zeros).
    pub fn nnz(&self) -> usize {
        self.as_design().nnz()
    }
    pub fn density(&self) -> f64 {
        self.as_design().density()
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, DesignStore::Dense(_))
    }

    /// Whether the stored values are f32-quantized (f32 shard / shard set):
    /// screening should widen keep-decisions by a safety slack
    /// (`PathConfig::safety_slack`, DESIGN.md §1).
    pub fn is_reduced_precision(&self) -> bool {
        match self {
            DesignStore::Mmap(x) => x.is_f32(),
            DesignStore::Sharded(x) => x.is_f32(),
            _ => false,
        }
    }

    /// Single element (sparse backends: O(log nnz-of-column) or a column
    /// stream — fine for I/O and tests, not for hot loops).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            DesignStore::Dense(x) => x.get(i, j),
            DesignStore::Csc(x) => x.get(i, j),
            DesignStore::Mmap(x) => {
                let mut out = [0.0];
                x.col_gather(j, &[i], &mut out);
                out[0]
            }
            DesignStore::Sharded(x) => x.get(i, j),
        }
    }

    /// The dense matrix inside, for dense-only call sites (PJRT literal
    /// upload, column-slice tests). Errors on any other backend with the
    /// explicit materialization routes — it must never abort a CLI path.
    pub fn dense(&self) -> Result<&DenseMatrix> {
        match self {
            DesignStore::Dense(x) => Ok(x),
            other => bail!(
                "expected the dense backend, found `{}`: materialize explicitly with \
                 to_dense()/into_dense(), or rerun with `--matrix dense`",
                other.backend_name()
            ),
        }
    }

    /// Mutable dense access (test fixtures that edit columns in place).
    /// Errors on a non-dense backend (same contract as [`DesignStore::dense`]).
    pub fn dense_mut(&mut self) -> Result<&mut DenseMatrix> {
        match self {
            DesignStore::Dense(x) => Ok(x),
            other => bail!(
                "expected the dense backend, found `{}`: materialize explicitly with \
                 to_dense()/into_dense(), or rerun with `--matrix dense`",
                other.backend_name()
            ),
        }
    }

    /// Materialize as dense (no copy when already dense).
    pub fn into_dense(self) -> DenseMatrix {
        match self {
            DesignStore::Dense(x) => x,
            other => other.to_dense(),
        }
    }

    /// Materialize as in-RAM CSC (no copy when already CSC).
    pub fn into_csc(self) -> CscMatrix {
        match self {
            DesignStore::Csc(x) => x,
            other => other.to_csc(),
        }
    }

    /// Dense copy of any backend.
    pub fn to_dense(&self) -> DenseMatrix {
        match self {
            DesignStore::Dense(x) => x.clone(),
            other => {
                let d = other.as_design();
                let mut out = DenseMatrix::zeros(d.n_rows(), d.n_cols());
                for j in 0..d.n_cols() {
                    d.col_into(j, out.col_mut(j));
                }
                out
            }
        }
    }

    /// In-RAM CSC copy of any backend (exact zeros dropped for dense).
    pub fn to_csc(&self) -> CscMatrix {
        match self {
            DesignStore::Dense(x) => CscMatrix::from_dense(x),
            DesignStore::Csc(x) => x.clone(),
            DesignStore::Mmap(x) => x.to_csc(),
            DesignStore::Sharded(x) => x.to_csc(),
        }
    }

    /// Screening sweep `out[j] = xⱼᵀw` (delegates to the backend kernel).
    pub fn gemv_t(&self, w: &[f64], out: &mut [f64]) {
        self.as_design().xt_w(w, out);
    }

    /// Dense `out = Xβ`.
    pub fn gemv(&self, beta: &[f64], out: &mut [f64]) {
        self.as_design().gemv(beta, out);
    }

    /// ℓ2 norm of every column.
    pub fn col_norms(&self) -> Vec<f64> {
        self.as_design().col_norms()
    }

    /// Scale every column to unit ℓ2 norm in place, returning the original
    /// norms. Supported for the in-RAM backends; an on-disk shard (set) is
    /// read-only, so this errors with the fix — normalize before
    /// converting, or load via `to_csc()` first.
    pub fn normalize_columns(&mut self) -> Result<Vec<f64>> {
        match self {
            DesignStore::Dense(x) => Ok(x.normalize_columns()),
            DesignStore::Csc(x) => Ok(x.normalize_columns()),
            other => bail!(
                "cannot normalize the read-only `{}` backend in place: normalize before \
                 `dpp convert`, or materialize with to_csc() first",
                other.backend_name()
            ),
        }
    }
}

impl PartialEq for DesignStore {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (DesignStore::Dense(a), DesignStore::Dense(b)) => a == b,
            (DesignStore::Csc(a), DesignStore::Csc(b)) => a == b,
            (DesignStore::Mmap(a), DesignStore::Mmap(b)) => a.shard_dir() == b.shard_dir(),
            (DesignStore::Sharded(a), DesignStore::Sharded(b)) => a == b,
            _ => false,
        }
    }
}

impl From<DenseMatrix> for DesignStore {
    fn from(x: DenseMatrix) -> DesignStore {
        DesignStore::Dense(x)
    }
}

impl From<CscMatrix> for DesignStore {
    fn from(x: CscMatrix) -> DesignStore {
        DesignStore::Csc(x)
    }
}

impl From<MmapCscMatrix> for DesignStore {
    fn from(x: MmapCscMatrix) -> DesignStore {
        DesignStore::Mmap(x)
    }
}

impl From<ShardSetMatrix> for DesignStore {
    fn from(x: ShardSetMatrix) -> DesignStore {
        DesignStore::Sharded(x)
    }
}

/// Full delegation, so the provided-method overrides of each backend (the
/// 8-way dense sweep, CSC merge-joins, the shard's streaming kernels, the
/// shard set's pool-parallel sweeps) are reached through the store exactly
/// as through the inner type.
impl DesignMatrix for DesignStore {
    fn n_rows(&self) -> usize {
        self.as_design().n_rows()
    }

    fn n_cols(&self) -> usize {
        self.as_design().n_cols()
    }

    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        self.as_design().xt_w(w, out);
    }

    fn col_dot_w(&self, j: usize, w: &[f64]) -> f64 {
        self.as_design().col_dot_w(j, w)
    }

    fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]) {
        self.as_design().col_axpy_into(j, a, out);
    }

    fn col_sq_norm(&self, j: usize) -> f64 {
        self.as_design().col_sq_norm(j)
    }

    fn col_dot_col(&self, i: usize, j: usize) -> f64 {
        self.as_design().col_dot_col(i, j)
    }

    fn col_into(&self, j: usize, out: &mut [f64]) {
        self.as_design().col_into(j, out);
    }

    fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]) {
        self.as_design().col_gather(j, rows, out);
    }

    fn nnz(&self) -> usize {
        self.as_design().nnz()
    }

    fn data_version(&self) -> u64 {
        self.as_design().data_version()
    }

    fn density(&self) -> f64 {
        self.as_design().density()
    }

    fn col_norms(&self) -> Vec<f64> {
        self.as_design().col_norms()
    }

    fn xt_w_subset(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        self.as_design().xt_w_subset(cols, w, out);
    }

    fn accum_cols(&self, cols: &[usize], beta: &[f64], out: &mut [f64]) {
        self.as_design().accum_cols(cols, beta, out);
    }

    fn gemv(&self, beta: &[f64], out: &mut [f64]) {
        self.as_design().gemv(beta, out);
    }

    fn op_norm_sq_subset(&self, cols: &[usize], iters: usize, seed: u64) -> f64 {
        self.as_design().op_norm_sq_subset(cols, iters, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dense() -> DenseMatrix {
        DenseMatrix::from_rows(&[vec![1.0, 0.0, 3.0], vec![0.0, 5.0, 6.0]])
    }

    #[test]
    fn variants_agree_through_the_trait() {
        let d = DesignStore::from(small_dense());
        let c = DesignStore::from(CscMatrix::from_dense(&small_dense()));
        let s = DesignStore::from(ShardSetMatrix::split_csc(
            &CscMatrix::from_dense(&small_dense()),
            2,
        ));
        assert_eq!((d.n_rows(), d.n_cols()), (2, 3));
        assert_eq!((c.n_rows(), c.n_cols()), (2, 3));
        assert_eq!((s.n_rows(), s.n_cols()), (2, 3));
        assert_eq!(d.nnz(), 6); // dense counts stored entries
        assert_eq!(c.nnz(), 4);
        assert_eq!(s.nnz(), 4);
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        let mut e = vec![0.0; 3];
        d.gemv_t(&[1.0, -1.0], &mut a);
        c.gemv_t(&[1.0, -1.0], &mut b);
        s.gemv_t(&[1.0, -1.0], &mut e);
        assert_eq!(a, b);
        assert_eq!(b, e);
        for i in 0..2 {
            for j in 0..3 {
                assert_eq!(d.get(i, j), c.get(i, j), "({i},{j})");
                assert_eq!(c.get(i, j), s.get(i, j), "sharded ({i},{j})");
            }
        }
    }

    #[test]
    fn conversions_round_trip() {
        let d = DesignStore::from(small_dense());
        let c = DesignStore::from(d.to_csc());
        assert_eq!(c.to_dense(), small_dense());
        assert_eq!(c.clone().into_csc(), d.to_csc());
        assert_eq!(c.into_dense(), small_dense());
        assert!(d.is_dense());
        assert_eq!(d.backend_name(), "dense");
        let s = DesignStore::from(ShardSetMatrix::split_csc(&d.to_csc(), 3));
        assert_eq!(s.backend_name(), "sharded");
        assert_eq!(s.to_dense(), small_dense());
        assert_eq!(s.to_csc(), d.to_csc());
        assert!(!s.is_reduced_precision());
    }

    #[test]
    fn equality_is_per_variant() {
        let d1 = DesignStore::from(small_dense());
        let d2 = DesignStore::from(small_dense());
        let c = DesignStore::from(CscMatrix::from_dense(&small_dense()));
        assert_eq!(d1, d2);
        assert_ne!(d1, c); // cross-backend comparison is intentionally false
        let s1 = DesignStore::from(ShardSetMatrix::split_csc(&d1.to_csc(), 2));
        let s2 = DesignStore::from(ShardSetMatrix::split_csc(&d1.to_csc(), 2));
        assert_eq!(s1, s2);
        assert_ne!(s1, c);
    }

    #[test]
    fn normalize_matches_across_dense_and_csc() {
        let mut d = DesignStore::from(small_dense());
        let mut c = DesignStore::from(CscMatrix::from_dense(&small_dense()));
        let nd = d.normalize_columns().unwrap();
        let nc = c.normalize_columns().unwrap();
        assert_eq!(nd, nc);
        for (a, b) in d.col_norms().iter().zip(c.col_norms()) {
            assert!((a - 1.0).abs() < 1e-12 && (b - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn dense_only_accessors_error_on_sparse_with_guidance() {
        // the store.rs satellite fix: no process aborts from accessor
        // mismatches — a line-actionable error instead
        let mut c = DesignStore::from(CscMatrix::from_dense(&small_dense()));
        let err = format!("{:#}", c.dense().unwrap_err());
        assert!(err.contains("csc"), "{err}");
        assert!(err.contains("to_dense"), "{err}");
        assert!(c.dense_mut().is_err());
        let mut s = DesignStore::from(ShardSetMatrix::split_csc(
            &CscMatrix::from_dense(&small_dense()),
            2,
        ));
        let err = format!("{:#}", s.normalize_columns().unwrap_err());
        assert!(err.contains("sharded"), "{err}");
        assert!(err.contains("dpp convert"), "{err}");
        assert!(s.dense().is_err());
    }
}
