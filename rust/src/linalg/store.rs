//! Runtime-chosen matrix backend: [`DesignStore`] is the owned,
//! enum-dispatched counterpart of `&dyn DesignMatrix`.
//!
//! `data::Dataset` carries its feature matrix as a `DesignStore`, so a
//! dataset loaded from sparse LIBSVM input stays CSC end-to-end and a
//! dataset opened from an on-disk shard stays out-of-core — nothing
//! densifies on the way from I/O to screening (the bug this type fixes:
//! `read_libsvm` used to materialize a `DenseMatrix` before the backend
//! choice ever happened). The store implements [`DesignMatrix`] itself by
//! delegation, so `&ds.x` keeps coercing to `&dyn DesignMatrix` at every
//! rule/solver/path call site regardless of the variant inside.

use super::{CscMatrix, DenseMatrix, DesignMatrix, MmapCscMatrix};

/// Owned feature-matrix backend chosen at load time (or by `--matrix`).
#[derive(Clone, Debug)]
pub enum DesignStore {
    Dense(DenseMatrix),
    Csc(CscMatrix),
    Mmap(MmapCscMatrix),
}

impl DesignStore {
    /// Backend tag for reports (`dense` / `csc` / `mmap`).
    pub fn backend_name(&self) -> &'static str {
        match self {
            DesignStore::Dense(_) => "dense",
            DesignStore::Csc(_) => "csc",
            DesignStore::Mmap(_) => "mmap",
        }
    }

    /// Borrow as the matrix-free trait object.
    pub fn as_design(&self) -> &dyn DesignMatrix {
        match self {
            DesignStore::Dense(x) => x,
            DesignStore::Csc(x) => x,
            DesignStore::Mmap(x) => x,
        }
    }

    /// Box the inner backend for `ScreeningService::spawn_boxed`.
    pub fn into_boxed(self) -> Box<dyn DesignMatrix + Send> {
        match self {
            DesignStore::Dense(x) => Box::new(x),
            DesignStore::Csc(x) => Box::new(x),
            DesignStore::Mmap(x) => Box::new(x),
        }
    }

    pub fn n_rows(&self) -> usize {
        self.as_design().n_rows()
    }
    pub fn n_cols(&self) -> usize {
        self.as_design().n_cols()
    }
    /// Stored entries (dense: N·p; sparse backends: true non-zeros).
    pub fn nnz(&self) -> usize {
        self.as_design().nnz()
    }
    pub fn density(&self) -> f64 {
        self.as_design().density()
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, DesignStore::Dense(_))
    }

    /// Single element (sparse backends: O(log nnz-of-column) or a column
    /// stream — fine for I/O and tests, not for hot loops).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            DesignStore::Dense(x) => x.get(i, j),
            DesignStore::Csc(x) => x.get(i, j),
            DesignStore::Mmap(x) => {
                let mut out = [0.0];
                x.col_gather(j, &[i], &mut out);
                out[0]
            }
        }
    }

    /// The dense matrix inside, for dense-only call sites (PJRT literal
    /// upload, column-slice tests). Panics on a sparse backend.
    pub fn dense(&self) -> &DenseMatrix {
        match self {
            DesignStore::Dense(x) => x,
            other => panic!("expected dense backend, found {}", other.backend_name()),
        }
    }

    /// Mutable dense access (test fixtures that edit columns in place).
    /// Panics on a sparse backend.
    pub fn dense_mut(&mut self) -> &mut DenseMatrix {
        match self {
            DesignStore::Dense(x) => x,
            other => panic!("expected dense backend, found {}", other.backend_name()),
        }
    }

    /// Materialize as dense (no copy when already dense).
    pub fn into_dense(self) -> DenseMatrix {
        match self {
            DesignStore::Dense(x) => x,
            other => other.to_dense(),
        }
    }

    /// Materialize as in-RAM CSC (no copy when already CSC).
    pub fn into_csc(self) -> CscMatrix {
        match self {
            DesignStore::Csc(x) => x,
            other => other.to_csc(),
        }
    }

    /// Dense copy of any backend.
    pub fn to_dense(&self) -> DenseMatrix {
        match self {
            DesignStore::Dense(x) => x.clone(),
            other => {
                let d = other.as_design();
                let mut out = DenseMatrix::zeros(d.n_rows(), d.n_cols());
                for j in 0..d.n_cols() {
                    d.col_into(j, out.col_mut(j));
                }
                out
            }
        }
    }

    /// In-RAM CSC copy of any backend (exact zeros dropped for dense).
    pub fn to_csc(&self) -> CscMatrix {
        match self {
            DesignStore::Dense(x) => CscMatrix::from_dense(x),
            DesignStore::Csc(x) => x.clone(),
            DesignStore::Mmap(x) => x.to_csc(),
        }
    }

    /// Screening sweep `out[j] = xⱼᵀw` (delegates to the backend kernel).
    pub fn gemv_t(&self, w: &[f64], out: &mut [f64]) {
        self.as_design().xt_w(w, out);
    }

    /// Dense `out = Xβ`.
    pub fn gemv(&self, beta: &[f64], out: &mut [f64]) {
        self.as_design().gemv(beta, out);
    }

    /// ℓ2 norm of every column.
    pub fn col_norms(&self) -> Vec<f64> {
        self.as_design().col_norms()
    }

    /// Scale every column to unit ℓ2 norm in place, returning the original
    /// norms. Supported for the in-RAM backends; an out-of-core shard is
    /// read-only, so normalize before converting (or load it via
    /// `to_csc()` first).
    pub fn normalize_columns(&mut self) -> Vec<f64> {
        match self {
            DesignStore::Dense(x) => x.normalize_columns(),
            DesignStore::Csc(x) => x.normalize_columns(),
            DesignStore::Mmap(_) => panic!(
                "cannot normalize an out-of-core shard in place; normalize before `dpp convert`"
            ),
        }
    }
}

impl PartialEq for DesignStore {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (DesignStore::Dense(a), DesignStore::Dense(b)) => a == b,
            (DesignStore::Csc(a), DesignStore::Csc(b)) => a == b,
            (DesignStore::Mmap(a), DesignStore::Mmap(b)) => a.shard_dir() == b.shard_dir(),
            _ => false,
        }
    }
}

impl From<DenseMatrix> for DesignStore {
    fn from(x: DenseMatrix) -> DesignStore {
        DesignStore::Dense(x)
    }
}

impl From<CscMatrix> for DesignStore {
    fn from(x: CscMatrix) -> DesignStore {
        DesignStore::Csc(x)
    }
}

impl From<MmapCscMatrix> for DesignStore {
    fn from(x: MmapCscMatrix) -> DesignStore {
        DesignStore::Mmap(x)
    }
}

/// Full delegation, so the provided-method overrides of each backend (the
/// 8-way dense sweep, CSC merge-joins, the shard's streaming kernels) are
/// reached through the store exactly as through the inner type.
impl DesignMatrix for DesignStore {
    fn n_rows(&self) -> usize {
        self.as_design().n_rows()
    }

    fn n_cols(&self) -> usize {
        self.as_design().n_cols()
    }

    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        self.as_design().xt_w(w, out);
    }

    fn col_dot_w(&self, j: usize, w: &[f64]) -> f64 {
        self.as_design().col_dot_w(j, w)
    }

    fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]) {
        self.as_design().col_axpy_into(j, a, out);
    }

    fn col_sq_norm(&self, j: usize) -> f64 {
        self.as_design().col_sq_norm(j)
    }

    fn col_dot_col(&self, i: usize, j: usize) -> f64 {
        self.as_design().col_dot_col(i, j)
    }

    fn col_into(&self, j: usize, out: &mut [f64]) {
        self.as_design().col_into(j, out);
    }

    fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]) {
        self.as_design().col_gather(j, rows, out);
    }

    fn nnz(&self) -> usize {
        self.as_design().nnz()
    }

    fn density(&self) -> f64 {
        self.as_design().density()
    }

    fn col_norms(&self) -> Vec<f64> {
        self.as_design().col_norms()
    }

    fn xt_w_subset(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        self.as_design().xt_w_subset(cols, w, out);
    }

    fn accum_cols(&self, cols: &[usize], beta: &[f64], out: &mut [f64]) {
        self.as_design().accum_cols(cols, beta, out);
    }

    fn gemv(&self, beta: &[f64], out: &mut [f64]) {
        self.as_design().gemv(beta, out);
    }

    fn op_norm_sq_subset(&self, cols: &[usize], iters: usize, seed: u64) -> f64 {
        self.as_design().op_norm_sq_subset(cols, iters, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dense() -> DenseMatrix {
        DenseMatrix::from_rows(&[vec![1.0, 0.0, 3.0], vec![0.0, 5.0, 6.0]])
    }

    #[test]
    fn variants_agree_through_the_trait() {
        let d = DesignStore::from(small_dense());
        let c = DesignStore::from(CscMatrix::from_dense(&small_dense()));
        assert_eq!((d.n_rows(), d.n_cols()), (2, 3));
        assert_eq!((c.n_rows(), c.n_cols()), (2, 3));
        assert_eq!(d.nnz(), 6); // dense counts stored entries
        assert_eq!(c.nnz(), 4);
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        d.gemv_t(&[1.0, -1.0], &mut a);
        c.gemv_t(&[1.0, -1.0], &mut b);
        assert_eq!(a, b);
        for i in 0..2 {
            for j in 0..3 {
                assert_eq!(d.get(i, j), c.get(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn conversions_round_trip() {
        let d = DesignStore::from(small_dense());
        let c = DesignStore::from(d.to_csc());
        assert_eq!(c.to_dense(), small_dense());
        assert_eq!(c.clone().into_csc(), d.to_csc());
        assert_eq!(c.into_dense(), small_dense());
        assert!(d.is_dense());
        assert_eq!(d.backend_name(), "dense");
    }

    #[test]
    fn equality_is_per_variant() {
        let d1 = DesignStore::from(small_dense());
        let d2 = DesignStore::from(small_dense());
        let c = DesignStore::from(CscMatrix::from_dense(&small_dense()));
        assert_eq!(d1, d2);
        assert_ne!(d1, c); // cross-backend comparison is intentionally false
    }

    #[test]
    fn normalize_matches_across_dense_and_csc() {
        let mut d = DesignStore::from(small_dense());
        let mut c = DesignStore::from(CscMatrix::from_dense(&small_dense()));
        let nd = d.normalize_columns();
        let nc = c.normalize_columns();
        assert_eq!(nd, nc);
        for (a, b) in d.col_norms().iter().zip(c.col_norms()) {
            assert!((a - 1.0).abs() < 1e-12 && (b - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn dense_accessor_panics_on_sparse() {
        let c = DesignStore::from(CscMatrix::from_dense(&small_dense()));
        let _ = c.dense();
    }
}
