//! Vector micro-kernels (BLAS level-1 equivalents) with 4-way unrolling.

/// Dot product `x·y`.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4 * 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let mut i = 0;
    while i < chunks {
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += x[i] * y[i];
        i += 1;
    }
    s
}

/// Sequential in-order sum — the sanctioned scalar fold (DESIGN.md §5).
///
/// One accumulator, slice order: this defines the exact FP sequence that
/// the bit-identity contract pins. `dpp audit` flags raw `.sum::<f64>()`
/// folds outside `linalg` so every reduction that can reach a numeric
/// result shares this sequence (or carries an explicit waiver).
#[inline]
pub fn seq_sum(x: &[f64]) -> f64 {
    let mut s = 0.0;
    for &v in x {
        s += v;
    }
    s
}

/// Mean via [`seq_sum`] (0.0 for empty input).
#[inline]
pub fn seq_mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    seq_sum(x) / x.len() as f64
}

/// `y += a·x`.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * xi;
    }
}

/// `x *= a`.
#[inline]
pub fn scale(a: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// ℓ2 norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// ℓ1 norm.
#[inline]
pub fn nrm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// ℓ∞ norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// Soft-threshold operator `S(z, t) = sign(z)·max(|z|−t, 0)` — the Lasso
/// proximal map, used by CD and FISTA.
#[inline]
pub fn soft_threshold(z: f64, t: f64) -> f64 {
    if z > t {
        z - t
    } else if z < -t {
        z + t
    } else {
        0.0
    }
}

/// Squared euclidean distance.
#[inline]
pub fn dist_sq(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y.iter()).map(|(a, b)| (a - b) * (a - b)).sum()
}

/// `‖s·x − y‖²` without materializing `s·x` (screening-rule radii).
#[inline]
pub fn dist_sq_scaled(x: &[f64], s: f64, y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y.iter()).map(|(a, b)| (s * a - b) * (s * a - b)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn dot_unrolled_matches_naive() {
        prop::check("dot unrolled == naive", 0xB1, 50, |rng| {
            let n = rng.usize(33);
            let mut x = vec![0.0; n];
            let mut y = vec![0.0; n];
            rng.fill_normal(&mut x);
            rng.fill_normal(&mut y);
            let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - naive).abs() < 1e-10 * (1.0 + naive.abs()));
        });
    }

    #[test]
    fn axpy_scale_norms() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(2.0, &[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![1.5, 2.0, 2.5]);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert_eq!(nrm1(&[-1.0, 2.0, -3.0]), 6.0);
        assert_eq!(nrm_inf(&[-1.0, 2.0, -3.0]), 3.0);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn soft_threshold_is_prox_of_abs() {
        // S(z,t) minimizes 0.5(b−z)² + t|b|: check stationarity numerically.
        prop::check("soft-threshold prox optimality", 0xB2, 40, |rng| {
            let z = rng.uniform(-5.0, 5.0);
            let t = rng.uniform(0.0, 3.0);
            let b = soft_threshold(z, t);
            let obj = |b: f64| 0.5 * (b - z) * (b - z) + t * b.abs();
            let fb = obj(b);
            for db in [-1e-4, 1e-4, -0.1, 0.1] {
                assert!(obj(b + db) >= fb - 1e-12, "z={z} t={t} b={b}");
            }
        });
    }

    #[test]
    fn dist_sq_basic() {
        assert_eq!(dist_sq(&[1.0, 2.0], &[4.0, 6.0]), 25.0);
    }
}
