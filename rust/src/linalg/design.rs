//! The matrix-free `DesignMatrix` abstraction (DESIGN.md §2).
//!
//! Every screening rule in the paper is stated in terms of two primitives —
//! the correlation sweep `Xᵀw` and per-column inner products — never element
//! access, and every solver substrate adds only column-local axpy/dot
//! updates. `DesignMatrix` captures exactly that contract, so screening,
//! solvers, path drivers, and the service run unchanged over a dense
//! column-major matrix, a CSC sparse matrix, or any future out-of-core /
//! sharded backend. The paper's §1 motivation ("we may not even be able to
//! load the data matrix into main memory") is the reason the contract is
//! matrix-free: nothing in the rule/solver layers may assume O(1) element
//! access or a materialized column slice.
//!
//! Required methods are the minimal per-backend kernels; everything else
//! (subset sweeps, accumulation, power iteration, column norms) has a
//! default implementation built on them. Backends override defaults only
//! when a faster fused kernel exists (e.g. the 8-way unrolled dense sweep).

use super::ops::{nrm2, scale};

/// Matrix-free view of the N×p feature matrix X.
///
/// Object safe: the screening context, solvers and the service hold
/// `&dyn DesignMatrix` / `Box<dyn DesignMatrix + Send>`.
pub trait DesignMatrix {
    /// N — number of samples (rows).
    fn n_rows(&self) -> usize;

    /// p — number of features (columns).
    fn n_cols(&self) -> usize;

    /// Correlation sweep: `out[j] = xⱼᵀ w` for every column j. The O(nnz)
    /// hot spot of every screening rule.
    fn xt_w(&self, w: &[f64], out: &mut [f64]);

    /// `xⱼᵀ w` for a single column (coordinate-descent inner step).
    fn col_dot_w(&self, j: usize, w: &[f64]) -> f64;

    /// `out += a·xⱼ` (scatter-axpy; residual updates).
    fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]);

    /// `‖xⱼ‖²`.
    fn col_sq_norm(&self, j: usize) -> f64;

    /// Gram entry `xᵢᵀxⱼ` (LARS Cholesky updates).
    fn col_dot_col(&self, i: usize, j: usize) -> f64;

    /// Densify column j into `out` (length N, overwritten). Used only on
    /// O(1)-many columns per path (the λmax-attaining feature of eq. (17)),
    /// never inside per-feature loops.
    fn col_into(&self, j: usize, out: &mut [f64]);

    /// Gather a row subset of column j: `out[k] = X[rows[k], j]`
    /// (row-subsampling workloads — stability selection, CV folds).
    fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]);

    /// Stored entries (dense: N·p; sparse: actual non-zeros).
    fn nnz(&self) -> usize;

    /// Fill fraction `nnz / (N·p)`.
    fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n_rows() * self.n_cols()).max(1) as f64
    }

    /// Monotone version stamp of the matrix *values*. Every shipped backend
    /// is immutable after construction and returns the default `0`; a
    /// future mutable backend (streaming appends, refreshed shards) must
    /// bump this on every change so long-lived caches of derived statistics
    /// ([`crate::screening::ContextStats`] in the serving sessions) can
    /// detect staleness instead of silently serving sweeps of data that no
    /// longer exists.
    fn data_version(&self) -> u64 {
        0
    }

    /// ℓ2 norm of every column.
    fn col_norms(&self) -> Vec<f64> {
        (0..self.n_cols()).map(|j| self.col_sq_norm(j).sqrt()).collect()
    }

    /// Like [`DesignMatrix::xt_w`] but only over the listed columns
    /// (screened / reduced problems).
    fn xt_w_subset(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), cols.len());
        for (k, &j) in cols.iter().enumerate() {
            out[k] = self.col_dot_w(j, w);
        }
    }

    /// `out += Σₖ betaₖ·x_{cols[k]}` — how solvers materialize Xβ for a
    /// reduced β.
    fn accum_cols(&self, cols: &[usize], beta: &[f64], out: &mut [f64]) {
        assert_eq!(cols.len(), beta.len());
        assert_eq!(out.len(), self.n_rows());
        for (k, &j) in cols.iter().enumerate() {
            if beta[k] != 0.0 {
                self.col_axpy_into(j, beta[k], out);
            }
        }
    }

    /// Dense `out = Xβ` for a full-length β (tests / reference use).
    fn gemv(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.n_cols());
        assert_eq!(out.len(), self.n_rows());
        out.fill(0.0);
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                self.col_axpy_into(j, b, out);
            }
        }
    }

    /// Spectral-norm upper bound `‖X[:,cols]‖²` via power iteration on the
    /// restricted XᵀX (FISTA step sizes, group Lipschitz constants).
    fn op_norm_sq_subset(&self, cols: &[usize], iters: usize, seed: u64) -> f64 {
        if cols.is_empty() {
            return 0.0;
        }
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut v: Vec<f64> = (0..cols.len()).map(|_| rng.normal()).collect();
        let nv = nrm2(&v);
        if nv == 0.0 {
            return 0.0;
        }
        scale(1.0 / nv, &mut v);
        let mut xb = vec![0.0; self.n_rows()];
        let mut w = vec![0.0; cols.len()];
        let mut lam = 0.0;
        for _ in 0..iters {
            xb.fill(0.0);
            self.accum_cols(cols, &v, &mut xb);
            self.xt_w_subset(cols, &xb, &mut w);
            lam = nrm2(&w);
            if lam == 0.0 {
                return 0.0;
            }
            for (vi, wi) in v.iter_mut().zip(w.iter()) {
                *vi = wi / lam;
            }
        }
        lam
    }
}

#[cfg(test)]
mod tests {
    use super::super::{CscMatrix, DenseMatrix};
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn random_sparse(n: usize, p: usize, density: f64, seed: u64) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let mut x = DenseMatrix::zeros(n, p);
        for j in 0..p {
            for v in x.col_mut(j).iter_mut() {
                if rng.f64() < density {
                    *v = rng.normal();
                }
            }
        }
        x
    }

    /// Every trait method must agree between the dense backend and the CSC
    /// backend built from the same data — the contract the whole crate
    /// relies on after the matrix-free redesign.
    #[test]
    fn dense_and_csc_agree_on_all_ops() {
        prop::check("DesignMatrix dense == csc", 0xDE51, 15, |rng| {
            let n = 2 + rng.usize(25);
            let p = 2 + rng.usize(35);
            let x = random_sparse(n, p, rng.uniform(0.1, 0.9), rng.next_u64());
            let csc = CscMatrix::from_dense(&x);
            let d: &dyn DesignMatrix = &x;
            let s: &dyn DesignMatrix = &csc;
            assert_eq!((d.n_rows(), d.n_cols()), (s.n_rows(), s.n_cols()));

            let mut w = vec![0.0; n];
            rng.fill_normal(&mut w);
            let mut a = vec![0.0; p];
            let mut b = vec![0.0; p];
            d.xt_w(&w, &mut a);
            s.xt_w(&w, &mut b);
            for j in 0..p {
                assert!((a[j] - b[j]).abs() < 1e-10 * (1.0 + a[j].abs()), "xt_w col {j}");
                assert!(
                    (d.col_dot_w(j, &w) - s.col_dot_w(j, &w)).abs() < 1e-10,
                    "col_dot_w {j}"
                );
                assert!(
                    (d.col_sq_norm(j) - s.col_sq_norm(j)).abs() < 1e-10,
                    "col_sq_norm {j}"
                );
            }

            let i = rng.usize(p);
            let j = rng.usize(p);
            assert!(
                (d.col_dot_col(i, j) - s.col_dot_col(i, j)).abs() < 1e-10,
                "col_dot_col ({i},{j})"
            );

            let mut da = vec![0.0; n];
            let mut sa = vec![0.0; n];
            d.col_axpy_into(j, 1.7, &mut da);
            s.col_axpy_into(j, 1.7, &mut sa);
            assert_eq!(da, sa, "col_axpy_into {j}");

            let mut dc = vec![1.0; n];
            let mut sc = vec![1.0; n];
            d.col_into(j, &mut dc);
            s.col_into(j, &mut sc);
            assert_eq!(dc, sc, "col_into {j}");

            let rows: Vec<usize> = (0..n).filter(|r| r % 2 == 0).collect();
            let mut dr = vec![0.0; rows.len()];
            let mut sr = vec![0.0; rows.len()];
            d.col_gather(j, &rows, &mut dr);
            s.col_gather(j, &rows, &mut sr);
            assert_eq!(dr, sr, "col_gather {j}");

            let mut beta = vec![0.0; p];
            rng.fill_normal(&mut beta);
            let mut dg = vec![0.0; n];
            let mut sg = vec![0.0; n];
            d.gemv(&beta, &mut dg);
            s.gemv(&beta, &mut sg);
            for i in 0..n {
                assert!((dg[i] - sg[i]).abs() < 1e-10 * (1.0 + dg[i].abs()), "gemv {i}");
            }
        });
    }

    #[test]
    fn nnz_and_density() {
        let x = random_sparse(10, 20, 0.3, 7);
        let csc = CscMatrix::from_dense(&x);
        let d: &dyn DesignMatrix = &x;
        let s: &dyn DesignMatrix = &csc;
        assert_eq!(d.nnz(), 200);
        assert!((d.density() - 1.0).abs() < 1e-15);
        assert!(s.nnz() < 200);
        assert!(s.density() < 1.0);
        // stored entries of the CSC match the dense matrix's true non-zeros
        let true_nnz = (0..20).map(|j| x.col(j).iter().filter(|v| **v != 0.0).count()).sum::<usize>();
        assert_eq!(s.nnz(), true_nnz);
    }

    #[test]
    fn op_norm_consistent_across_backends() {
        // one shared power iteration, running on each backend's kernels —
        // identical numbers for identical seeds
        let x = random_sparse(15, 12, 0.5, 9);
        let csc = CscMatrix::from_dense(&x);
        let cols: Vec<usize> = (0..12).collect();
        let a = DesignMatrix::op_norm_sq_subset(&x, &cols, 30, 42);
        let b = DesignMatrix::op_norm_sq_subset(&csc, &cols, 30, 42);
        assert!((a - b).abs() < 1e-9 * (1.0 + a), "{a} vs {b}");
    }
}
