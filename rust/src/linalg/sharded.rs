//! Row-sharded backend: [`ShardSetMatrix`] is a reducing facade that
//! implements the full [`DesignMatrix`] contract over a set of **row-range
//! shards** and executes its sweeps on the persistent worker pool
//! ([`crate::runtime::pool`]).
//!
//! A shard set splits the N×p design by rows: shard s owns the contiguous
//! row range `[row_start_s, row_start_{s+1})` and stores its slice as a
//! complete CSC triple over all p columns — either in RAM ([`CscMatrix`])
//! or out-of-core ([`MmapCscMatrix`] over a per-shard `dppcsc` directory,
//! DESIGN.md §2c). `data::convert::split_shard` writes shard sets from a
//! converted shard (`dpp shard --shards K`), and a `shardset.txt` manifest
//! ties the pieces together.
//!
//! ## Reduce semantics (why parity stays bit-exact)
//!
//! Every kernel reduces in **deterministic shard order**, with the split
//! chosen so each output element is produced by exactly one accumulator:
//!
//! * `xt_w` / `xt_w_subset` / `col_norms` parallelize over **column
//!   blocks**. Each column j is computed whole by one worker, which folds
//!   the shard contributions *in shard order into a single running
//!   accumulator*, entry by entry — the identical floating-point op
//!   sequence an in-RAM [`CscMatrix`] over the concatenated rows performs.
//!   Results are therefore bit-identical to CSC and independent of the
//!   thread count (`DPP_POOL_THREADS=1..k` all agree to the last bit —
//!   pinned in `rust/tests/backend_parity.rs`).
//! * `gemv` / `accum_cols` / `col_axpy_into` parallelize over **shards**:
//!   row ranges are disjoint, so each worker writes its own slice of the
//!   output, accumulating columns in the same order the CSC backend does.
//! * Per-column reads (`col_into`, `col_gather`, `col_dot_col`) gather
//!   per-shard segments in shard order.
//!
//! During a parallel sweep each worker takes a private window over every
//! mmap shard (a [`Clone`] reopens the shard, DESIGN.md §2), so readers at
//! different column offsets never thrash one shared pager.
//!
//! A shard may also live in another process entirely
//! ([`ShardBackend::Remote`], DESIGN.md §4b): the fold RPCs carry each
//! column's *running* accumulator to the node and back, so the reduce
//! order — and therefore every bit of every sweep — is unchanged.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::{CscMatrix, DesignMatrix, MmapCscMatrix};
use crate::net::RemoteShard;
use crate::runtime::pool::{self, WorkerPool};

/// Manifest file tying a shard-set directory together.
pub const SHARDSET_FILE: &str = "shardset.txt";

/// Column count below which sweeps stay single-threaded (dispatch overhead
/// beats the win on toy problems; the serial path is the same fold, so this
/// is a pure scheduling decision — never a numeric one).
pub const PAR_MIN_COLS: usize = 64;

/// One shard's storage: an in-RAM CSC slice, an out-of-core `dppcsc`
/// directory, or a connection to a `dpp shard-node` process hosting the
/// slice (DESIGN.md §4b). `n_rows` is the *local* row count of the slice;
/// row indices inside are shard-local (global row − `row_start`).
#[derive(Clone, Debug)]
pub enum ShardBackend {
    Csc(CscMatrix),
    Mmap(MmapCscMatrix),
    Remote(RemoteShard),
}

/// A remote shard op can only fail if the node is lost mid-sweep; the
/// sweep interface is infallible, so surface the line-actionable message
/// as a panic the coordinator's per-request `catch_unwind` converts into
/// `RequestError::SessionClosed` (never a hang, never a poisoned pool).
macro_rules! remote_or_panic {
    ($e:expr) => {
        $e.unwrap_or_else(|err| panic!("{err:#}"))
    };
}

impl ShardBackend {
    /// Local (slice) row count.
    pub fn n_rows(&self) -> usize {
        match self {
            ShardBackend::Csc(x) => x.n_rows(),
            ShardBackend::Mmap(x) => x.n_rows(),
            ShardBackend::Remote(x) => x.n_rows(),
        }
    }

    /// Column count (always the full p of the set).
    pub fn n_cols(&self) -> usize {
        match self {
            ShardBackend::Csc(x) => x.n_cols(),
            ShardBackend::Mmap(x) => x.n_cols(),
            ShardBackend::Remote(x) => x.n_cols(),
        }
    }

    /// Stored entries in this shard's row slice.
    pub fn nnz(&self) -> usize {
        match self {
            ShardBackend::Csc(x) => x.nnz(),
            ShardBackend::Mmap(x) => x.nnz(),
            ShardBackend::Remote(x) => x.nnz(),
        }
    }

    pub fn is_f32(&self) -> bool {
        match self {
            ShardBackend::Csc(_) => false,
            ShardBackend::Mmap(x) => x.is_f32(),
            ShardBackend::Remote(x) => x.is_f32(),
        }
    }

    /// Continue `*acc += Σ w_local[i]·v` over column j's entries, in row
    /// order, with the caller's single running accumulator — the fold that
    /// keeps the shard-order reduction bit-identical to one flat CSC sweep.
    /// `pub(crate)` so a `dpp shard-node` can serve it over the wire.
    pub(crate) fn fold_col_dot(&self, j: usize, w_local: &[f64], acc: &mut f64) {
        match self {
            ShardBackend::Csc(x) => {
                let (idx, vals) = x.col(j);
                let mut s = *acc;
                for (i, v) in idx.iter().zip(vals.iter()) {
                    s += w_local[*i as usize] * v;
                }
                *acc = s;
            }
            ShardBackend::Mmap(x) => {
                let mut s = *acc;
                x.for_col(j, |idx, vals| {
                    for (i, v) in idx.iter().zip(vals.iter()) {
                        s += w_local[*i as usize] * v;
                    }
                });
                *acc = s;
            }
            ShardBackend::Remote(rs) => {
                let mut a = [*acc];
                remote_or_panic!(rs.fold_cols_dot(&[j], w_local, &mut a));
                *acc = a[0];
            }
        }
    }

    /// Continue the folds of a whole column block at once — semantically
    /// `for k { fold_col_dot(cols.get(k), w_local, &mut accs[k]) }` (the
    /// per-column accumulators are independent, so the FP sequence of each
    /// is unchanged), but a remote shard serves the block in **one** RPC.
    fn fold_cols_dot(&self, cols: ColBlock<'_>, w_local: &[f64], accs: &mut [f64]) {
        match self {
            ShardBackend::Remote(rs) => {
                let cols: Vec<usize> = (0..accs.len()).map(|k| cols.get(k)).collect();
                remote_or_panic!(rs.fold_cols_dot(&cols, w_local, accs));
            }
            _ => {
                for (k, acc) in accs.iter_mut().enumerate() {
                    self.fold_col_dot(cols.get(k), w_local, acc);
                }
            }
        }
    }

    /// Continue `*acc += Σ v²` over column j's entries in row order.
    /// `pub(crate)` so a `dpp shard-node` can serve it over the wire.
    pub(crate) fn fold_col_sq_norm(&self, j: usize, acc: &mut f64) {
        match self {
            ShardBackend::Csc(x) => {
                let (_, vals) = x.col(j);
                let mut s = *acc;
                for v in vals {
                    s += v * v;
                }
                *acc = s;
            }
            ShardBackend::Mmap(x) => {
                let mut s = *acc;
                x.for_col(j, |_, vals| {
                    for v in vals {
                        s += v * v;
                    }
                });
                *acc = s;
            }
            ShardBackend::Remote(rs) => {
                let mut a = [*acc];
                remote_or_panic!(rs.fold_cols_sq_norm(&[j], &mut a));
                *acc = a[0];
            }
        }
    }

    /// Block form of [`ShardBackend::fold_col_sq_norm`], mirroring
    /// `fold_cols_dot`.
    fn fold_cols_sq_norm(&self, base: usize, accs: &mut [f64]) {
        match self {
            ShardBackend::Remote(rs) => {
                let cols: Vec<usize> = (base..base + accs.len()).collect();
                remote_or_panic!(rs.fold_cols_sq_norm(&cols, accs));
            }
            _ => {
                for (k, acc) in accs.iter_mut().enumerate() {
                    self.fold_col_sq_norm(base + k, acc);
                }
            }
        }
    }

    /// Continue the Gram merge-join `*acc += Σ_{matched rows} xᵢ·xⱼ` over
    /// this shard's (disjoint) row range, matches in row order.
    fn fold_col_dot_col(&self, i: usize, j: usize, acc: &mut f64) {
        match self {
            ShardBackend::Csc(x) => {
                let (ai, av) = x.col(i);
                let (bi, bv) = x.col(j);
                let (mut a, mut b) = (0usize, 0usize);
                let mut s = *acc;
                while a < ai.len() && b < bi.len() {
                    match ai[a].cmp(&bi[b]) {
                        std::cmp::Ordering::Less => a += 1,
                        std::cmp::Ordering::Greater => b += 1,
                        std::cmp::Ordering::Equal => {
                            s += av[a] * bv[b];
                            a += 1;
                            b += 1;
                        }
                    }
                }
                *acc = s;
            }
            ShardBackend::Remote(rs) => {
                // fetch both sparse columns and re-run the exact CSC
                // merge-join locally — same matches, same FP order
                let (ai, av) = remote_or_panic!(rs.fetch_col(i));
                let (bi, bv) = remote_or_panic!(rs.fetch_col(j));
                let (mut a, mut b) = (0usize, 0usize);
                let mut s = *acc;
                while a < ai.len() && b < bi.len() {
                    match ai[a].cmp(&bi[b]) {
                        std::cmp::Ordering::Less => a += 1,
                        std::cmp::Ordering::Greater => b += 1,
                        std::cmp::Ordering::Equal => {
                            s += av[a] * bv[b];
                            a += 1;
                            b += 1;
                        }
                    }
                }
                *acc = s;
            }
            ShardBackend::Mmap(x) => {
                // column i materialized (bounded by its local nnz), column j
                // streamed — the same scheme MmapCscMatrix::col_dot_col uses
                let mut ai: Vec<u32> = Vec::new();
                let mut av: Vec<f64> = Vec::new();
                x.for_col(i, |ii, vv| {
                    ai.extend_from_slice(ii);
                    av.extend_from_slice(vv);
                });
                let mut a = 0usize;
                let mut s = *acc;
                x.for_col(j, |bi, bv| {
                    for (b, v) in bi.iter().zip(bv.iter()) {
                        while a < ai.len() && ai[a] < *b {
                            a += 1;
                        }
                        if a < ai.len() && ai[a] == *b {
                            s += av[a] * v;
                        }
                    }
                });
                *acc = s;
            }
        }
    }

    /// `out_local += a·xⱼ` over this shard's row slice.
    fn col_axpy_into(&self, j: usize, a: f64, out_local: &mut [f64]) {
        match self {
            ShardBackend::Csc(x) => x.col_axpy(j, a, out_local),
            ShardBackend::Mmap(x) => DesignMatrix::col_axpy_into(x, j, a, out_local),
            ShardBackend::Remote(rs) => {
                // same per-entry `out[i] += a·v` sequence CscMatrix::col_axpy
                // runs, on the fetched sparse column
                let (idx, vals) = remote_or_panic!(rs.fetch_col(j));
                for (i, v) in idx.iter().zip(vals.iter()) {
                    out_local[*i as usize] += a * v;
                }
            }
        }
    }

    /// Densify column j into this shard's slice (overwrites all of it).
    fn col_into(&self, j: usize, out_local: &mut [f64]) {
        match self {
            ShardBackend::Csc(x) => DesignMatrix::col_into(x, j, out_local),
            ShardBackend::Mmap(x) => DesignMatrix::col_into(x, j, out_local),
            ShardBackend::Remote(rs) => {
                let (idx, vals) = remote_or_panic!(rs.fetch_col(j));
                out_local.fill(0.0);
                for (i, v) in idx.iter().zip(vals.iter()) {
                    out_local[*i as usize] = *v;
                }
            }
        }
    }

    /// Gather shard-local rows of column j.
    fn col_gather(&self, j: usize, rows_local: &[usize], out: &mut [f64]) {
        match self {
            ShardBackend::Csc(x) => DesignMatrix::col_gather(x, j, rows_local, out),
            ShardBackend::Mmap(x) => DesignMatrix::col_gather(x, j, rows_local, out),
            ShardBackend::Remote(rs) => {
                // pure value copies (binary search per requested row) — no
                // FP arithmetic, so exactness is trivial
                let (idx, vals) = remote_or_panic!(rs.fetch_col(j));
                for (o, &r) in out.iter_mut().zip(rows_local.iter()) {
                    *o = match idx.binary_search(&(r as u32)) {
                        Ok(k) => vals[k],
                        Err(_) => 0.0,
                    };
                }
            }
        }
    }

    /// Visit column j's `(local_row, value)` entries in row order.
    /// `pub(crate)` so a `dpp shard-node` can serve columns over the wire.
    pub(crate) fn for_col_entries(&self, j: usize, mut f: impl FnMut(u32, f64)) {
        match self {
            ShardBackend::Csc(x) => {
                let (idx, vals) = x.col(j);
                for (i, v) in idx.iter().zip(vals.iter()) {
                    f(*i, *v);
                }
            }
            ShardBackend::Mmap(x) => x.for_col(j, |idx, vals| {
                for (i, v) in idx.iter().zip(vals.iter()) {
                    f(*i, *v);
                }
            }),
            ShardBackend::Remote(rs) => {
                let (idx, vals) = remote_or_panic!(rs.fetch_col(j));
                for (i, v) in idx.iter().zip(vals.iter()) {
                    f(*i, *v);
                }
            }
        }
    }

    /// A private-window handle for a parallel sweep worker: mmap shards are
    /// reopened (independent pager, no lock contention or window thrash);
    /// in-RAM shards are shared as-is (`None`). A failed reopen (fd
    /// pressure, unlinked dir) also returns `None`, degrading to the shared
    /// Mutex window — slower, never wrong, and never a worker panic. A
    /// per-pool-worker persistent window cache (reopen once per worker
    /// instead of once per job) is the known follow-up if reopen cost ever
    /// shows up in `BENCH_screen.json`.
    fn private_window_clone(&self) -> Option<ShardBackend> {
        match self {
            ShardBackend::Csc(_) => None,
            ShardBackend::Mmap(x) => {
                MmapCscMatrix::open_with_budget(x.shard_dir(), x.window_budget())
                    .ok()
                    .map(ShardBackend::Mmap)
            }
            // independent socket per sweep worker; a failed dial degrades
            // the worker to the shared mutexed connection — slower, never
            // wrong
            ShardBackend::Remote(rs) => rs.reconnect().map(ShardBackend::Remote),
        }
    }
}

/// One row-range shard: where its rows start globally, and its storage.
#[derive(Clone, Debug)]
pub struct RowShard {
    pub row_start: usize,
    backend: ShardBackend,
}

impl RowShard {
    pub fn backend(&self) -> &ShardBackend {
        &self.backend
    }
}

/// Row-sharded design matrix: the reducing facade over a set of row-range
/// shards. Implements the complete [`DesignMatrix`] contract, so screening
/// rules, solvers, path drivers and `ScreeningService::spawn_boxed` take it
/// unchanged (DESIGN.md §2).
pub struct ShardSetMatrix {
    shards: Vec<RowShard>,
    /// Shard row offsets; `row_starts[s]..row_starts[s+1]` is shard s's
    /// global row range, `row_starts[K] == n_rows`.
    row_starts: Vec<usize>,
    n_rows: usize,
    n_cols: usize,
    nnz: usize,
    /// Manifest directory when opened from disk (identity for `PartialEq`).
    dir: Option<PathBuf>,
    /// Any source shard stored f32 values. Tracked here (not only on the
    /// backends) so `open_in_ram` — which widens the slices to in-RAM f64
    /// CSC — still reports the quantization and keeps the safety-slack
    /// contract (DESIGN.md §1).
    f32_values: bool,
    /// Pool override (benches sweep thread counts); `None` → the global
    /// `DPP_POOL_THREADS`-sized pool.
    pool: Option<Arc<WorkerPool>>,
}

impl Clone for ShardSetMatrix {
    fn clone(&self) -> ShardSetMatrix {
        ShardSetMatrix {
            shards: self.shards.clone(),
            row_starts: self.row_starts.clone(),
            n_rows: self.n_rows,
            n_cols: self.n_cols,
            nnz: self.nnz,
            dir: self.dir.clone(),
            f32_values: self.f32_values,
            pool: self.pool.clone(),
        }
    }
}

impl std::fmt::Debug for ShardSetMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardSetMatrix")
            .field("shards", &self.shards.len())
            .field("n_rows", &self.n_rows)
            .field("n_cols", &self.n_cols)
            .field("nnz", &self.nnz)
            .field("dir", &self.dir)
            .finish()
    }
}

impl PartialEq for ShardSetMatrix {
    fn eq(&self, other: &Self) -> bool {
        if let (Some(a), Some(b)) = (&self.dir, &other.dir) {
            return a == b;
        }
        self.row_starts == other.row_starts
            && self
                .shards
                .iter()
                .zip(other.shards.iter())
                .all(|(a, b)| match (&a.backend, &b.backend) {
                    (ShardBackend::Csc(x), ShardBackend::Csc(y)) => x == y,
                    (ShardBackend::Mmap(x), ShardBackend::Mmap(y)) => {
                        x.shard_dir() == y.shard_dir()
                    }
                    (ShardBackend::Remote(x), ShardBackend::Remote(y)) => x == y,
                    _ => false,
                })
    }
}

/// Balanced row boundaries: `k+1` offsets with shard s owning
/// `[splits[s], splits[s+1])`. Shards may be empty when `k > n`.
pub fn row_splits(n: usize, k: usize) -> Vec<usize> {
    assert!(k >= 1);
    (0..=k).map(|s| s * n / k).collect()
}

impl ShardSetMatrix {
    /// Assemble from in-RAM CSC slices stacked in row order (each over all
    /// p columns). The main constructor for tests, benches and
    /// `DPP_MATRIX=sharded` experiment runs.
    pub fn from_csc_shards(parts: Vec<CscMatrix>) -> ShardSetMatrix {
        assert!(!parts.is_empty(), "a shard set needs at least one shard");
        let n_cols = parts[0].n_cols();
        let mut shards = Vec::with_capacity(parts.len());
        let mut row_starts = Vec::with_capacity(parts.len() + 1);
        let mut row = 0usize;
        let mut nnz = 0usize;
        row_starts.push(0);
        for x in parts {
            assert_eq!(x.n_cols(), n_cols, "all shards must span the same columns");
            let start = row;
            row += x.n_rows();
            nnz += x.nnz();
            row_starts.push(row);
            shards.push(RowShard { row_start: start, backend: ShardBackend::Csc(x) });
        }
        ShardSetMatrix {
            shards,
            row_starts,
            n_rows: row,
            n_cols,
            nnz,
            dir: None,
            f32_values: false,
            pool: None,
        }
    }

    /// Split an in-RAM CSC into `k` balanced row-range shards.
    pub fn split_csc(x: &CscMatrix, k: usize) -> ShardSetMatrix {
        Self::split_csc_at(x, &row_splits(x.n_rows(), k))
    }

    /// Split at explicit row boundaries (`splits[0] == 0`, ascending,
    /// `splits[last] == n_rows`) — lets tests place a boundary anywhere,
    /// including mid-way through a dense row block or creating empty shards.
    pub fn split_csc_at(x: &CscMatrix, splits: &[usize]) -> ShardSetMatrix {
        assert!(splits.len() >= 2, "need at least one shard");
        assert_eq!(splits[0], 0);
        assert_eq!(*splits.last().unwrap(), x.n_rows());
        let p = x.n_cols();
        let mut parts = Vec::with_capacity(splits.len() - 1);
        for s in 0..splits.len() - 1 {
            assert!(splits[s] <= splits[s + 1], "splits must ascend");
            let (lo, hi) = (splits[s] as u32, splits[s + 1] as u32);
            let mut col_ptr = Vec::with_capacity(p + 1);
            col_ptr.push(0usize);
            let mut row_idx = Vec::new();
            let mut values = Vec::new();
            for j in 0..p {
                let (idx, vals) = x.col(j);
                let a = idx.partition_point(|&i| i < lo);
                let b = idx.partition_point(|&i| i < hi);
                for (i, v) in idx[a..b].iter().zip(vals[a..b].iter()) {
                    row_idx.push(i - lo);
                    values.push(*v);
                }
                col_ptr.push(row_idx.len());
            }
            parts.push(CscMatrix::from_parts((hi - lo) as usize, p, col_ptr, row_idx, values));
        }
        Self::from_csc_shards(parts)
    }

    /// Open a shard-set directory (`shardset.txt` manifest written by
    /// `dpp shard`) with every shard out-of-core, each paging through its
    /// own `budget_bytes` window.
    pub fn open_with_budget(
        dir: impl AsRef<Path>,
        budget_bytes: usize,
    ) -> Result<ShardSetMatrix> {
        Self::open_impl(dir.as_ref(), budget_bytes, false)
    }

    /// Open with the default window budget (`DPP_MMAP_BUDGET` if set).
    pub fn open(dir: impl AsRef<Path>) -> Result<ShardSetMatrix> {
        Self::open_impl(dir.as_ref(), super::mmap::default_budget(), false)
    }

    /// Open a shard set with every shard loaded into RAM as CSC (small
    /// problems / maximum sweep throughput).
    pub fn open_in_ram(dir: impl AsRef<Path>) -> Result<ShardSetMatrix> {
        Self::open_impl(dir.as_ref(), super::mmap::DEFAULT_WINDOW_BYTES, true)
    }

    /// Assemble from already-connected [`RemoteShard`]s stacked in row
    /// order — each one a `dpp shard-node` process hosting a row slice
    /// (DESIGN.md §4b). Sweeps become scatter/gather RPCs with the same
    /// shard-order reduce as local execution.
    pub fn from_remote_shards(remotes: Vec<RemoteShard>) -> Result<ShardSetMatrix> {
        if remotes.is_empty() {
            bail!("a remote shard set needs at least one shard node");
        }
        let n_cols = remotes[0].n_cols();
        let mut shards = Vec::with_capacity(remotes.len());
        let mut row_starts = Vec::with_capacity(remotes.len() + 1);
        row_starts.push(0);
        let mut row = 0usize;
        let mut nnz = 0usize;
        let mut f32_values = false;
        for rs in remotes {
            if rs.n_cols() != n_cols {
                bail!(
                    "shard node {} spans {} columns, the first node spans {n_cols} \
                     — all shards must cover the same columns",
                    rs.addr(),
                    rs.n_cols()
                );
            }
            let start = row;
            row += rs.n_rows();
            nnz += rs.nnz();
            row_starts.push(row);
            f32_values |= rs.is_f32();
            shards.push(RowShard { row_start: start, backend: ShardBackend::Remote(rs) });
        }
        Ok(ShardSetMatrix {
            shards,
            row_starts,
            n_rows: row,
            n_cols,
            nnz,
            dir: None,
            f32_values,
            pool: None,
        })
    }

    /// Dial shard nodes (row order = address order) and assemble the set.
    pub fn connect(addrs: &[String]) -> Result<ShardSetMatrix> {
        let remotes = addrs
            .iter()
            .map(|a| RemoteShard::connect(a))
            .collect::<Result<Vec<_>>>()?;
        Self::from_remote_shards(remotes)
    }

    fn open_impl(dir: &Path, budget_bytes: usize, in_ram: bool) -> Result<ShardSetMatrix> {
        let meta = read_shardset_meta(dir)?;
        let mut shards = Vec::with_capacity(meta.shards.len());
        let mut row_starts = Vec::with_capacity(meta.shards.len() + 1);
        row_starts.push(0);
        let mut row = 0usize;
        let mut nnz = 0usize;
        let mut f32_values = false;
        for e in &meta.shards {
            if e.row_offset != row {
                bail!(
                    "shardset {dir:?}: shard `{}` starts at row {} (expected {row})",
                    e.dir,
                    e.row_offset
                );
            }
            let mm = MmapCscMatrix::open_with_budget(dir.join(&e.dir), budget_bytes)
                .with_context(|| format!("opening shard `{}` of {dir:?}", e.dir))?;
            if mm.n_rows() != e.n_rows {
                bail!(
                    "shardset {dir:?}: shard `{}` has {} rows, manifest says {}",
                    e.dir,
                    mm.n_rows(),
                    e.n_rows
                );
            }
            if mm.n_cols() != meta.n_cols {
                bail!(
                    "shardset {dir:?}: shard `{}` spans {} columns, manifest says {}",
                    e.dir,
                    mm.n_cols(),
                    meta.n_cols
                );
            }
            if mm.nnz() != e.nnz {
                bail!(
                    "shardset {dir:?}: shard `{}` holds {} entries, manifest says {}",
                    e.dir,
                    mm.nnz(),
                    e.nnz
                );
            }
            row += e.n_rows;
            nnz += e.nnz;
            row_starts.push(row);
            f32_values |= mm.is_f32();
            let backend = if in_ram {
                ShardBackend::Csc(mm.to_csc())
            } else {
                ShardBackend::Mmap(mm)
            };
            shards.push(RowShard { row_start: e.row_offset, backend });
        }
        if row != meta.n_rows {
            bail!("shardset {dir:?}: shards cover {row} rows, manifest says {}", meta.n_rows);
        }
        if nnz != meta.nnz {
            bail!("shardset {dir:?}: shards hold {nnz} entries, manifest says {}", meta.nnz);
        }
        Ok(ShardSetMatrix {
            shards,
            row_starts,
            n_rows: meta.n_rows,
            n_cols: meta.n_cols,
            nnz,
            dir: Some(dir.to_path_buf()),
            f32_values,
            pool: None,
        })
    }

    /// Use a specific worker pool instead of the global one (benches sweep
    /// thread counts this way; results are bit-identical either way).
    pub fn with_pool(mut self, pool: Arc<WorkerPool>) -> ShardSetMatrix {
        self.pool = Some(pool);
        self
    }

    fn pool(&self) -> &WorkerPool {
        match &self.pool {
            Some(p) => p,
            None => pool::global(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[RowShard] {
        &self.shards
    }

    /// Shard row offsets (`len == shard_count() + 1`, last == n_rows).
    pub fn row_starts(&self) -> &[usize] {
        &self.row_starts
    }

    /// Manifest directory when opened from disk.
    pub fn set_dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Whether any shard stores (or was loaded from) f32-quantized values
    /// — true even after `open_in_ram` widens the slices to f64 CSC, so
    /// screening still applies the safety slack (DESIGN.md §1).
    pub fn is_f32(&self) -> bool {
        self.f32_values || self.shards.iter().any(|s| s.backend.is_f32())
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Concatenate the shards back into one in-RAM [`CscMatrix`] (tests,
    /// `--matrix csc` on a shard-set input).
    pub fn to_csc(&self) -> CscMatrix {
        let mut col_ptr = Vec::with_capacity(self.n_cols + 1);
        col_ptr.push(0usize);
        let mut row_idx: Vec<u32> = Vec::with_capacity(self.nnz);
        let mut values: Vec<f64> = Vec::with_capacity(self.nnz);
        for j in 0..self.n_cols {
            for s in &self.shards {
                let off = s.row_start as u32;
                s.backend.for_col_entries(j, |i, v| {
                    row_idx.push(i + off);
                    values.push(v);
                });
            }
            col_ptr.push(values.len());
        }
        CscMatrix::from_parts(self.n_rows, self.n_cols, col_ptr, row_idx, values)
    }

    /// Single element (shard lookup + per-shard gather — I/O and tests).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let mut out = [0.0];
        DesignMatrix::col_gather(self, j, &[i], &mut out);
        out[0]
    }

    /// Fold column j's dot product with `w` across shards in shard order
    /// (one running accumulator — see the module docs on bit-exactness).
    fn fold_full_col_dot(&self, j: usize, w: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (s, win) in self.shards.iter().zip(self.row_starts.windows(2)) {
            s.backend.fold_col_dot(j, &w[win[0]..win[1]], &mut acc);
        }
        acc
    }

    /// Compute `out[k] = x_{cols[k]}ᵀ w` for a column block, optionally
    /// through private mmap windows (parallel workers).
    ///
    /// The loop nest is shards-outer / columns-inner: every column's
    /// accumulator is independent, so each still folds shard 0's entries,
    /// then shard 1's, … — the identical per-column FP sequence the old
    /// columns-outer nest produced — while a remote shard serves the whole
    /// block in one scatter/gather RPC per shard instead of one per column.
    fn sweep_cols_into(
        &self,
        cols: ColBlock<'_>,
        w: &[f64],
        out: &mut [f64],
        private_windows: bool,
    ) {
        let owned: Vec<Option<ShardBackend>> = if private_windows {
            self.shards.iter().map(|s| s.backend.private_window_clone()).collect()
        } else {
            self.shards.iter().map(|_| None).collect()
        };
        out.fill(0.0);
        for ((s, win), ow) in
            self.shards.iter().zip(self.row_starts.windows(2)).zip(owned.iter())
        {
            let b = ow.as_ref().unwrap_or(&s.backend);
            b.fold_cols_dot(cols, &w[win[0]..win[1]], out);
        }
    }

    /// Run `f(backend, out_slice)` once per shard over its disjoint row
    /// slice — in shard order serially, or as one pool job per shard
    /// (bit-identical either way: the slices never overlap and each shard
    /// applies columns in caller order). Shared by `gemv` / `accum_cols`.
    fn for_row_slices(&self, out: &mut [f64], f: impl Fn(&ShardBackend, &mut [f64]) + Sync) {
        assert_eq!(out.len(), self.n_rows);
        if self.pool().threads() <= 1 || self.shards.len() <= 1 {
            for (s, win) in self.shards.iter().zip(self.row_starts.windows(2)) {
                f(&s.backend, &mut out[win[0]..win[1]]);
            }
            return;
        }
        let f = &f; // shared by every job (jobs only borrow)
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        let mut rest = &mut out[..];
        let mut prev = 0usize;
        for (s, win) in self.shards.iter().zip(self.row_starts.windows(2)) {
            let (head, tail) = rest.split_at_mut(win[1] - prev);
            rest = tail;
            prev = win[1];
            let backend = &s.backend;
            jobs.push(Box::new(move || f(backend, head)));
        }
        self.pool().run(jobs);
    }

    /// Split `out` into contiguous column chunks and run
    /// `f(base_index, chunk, private_windows)` on each — serially below
    /// [`PAR_MIN_COLS`], else one pool job per chunk. Shared by `xt_w` /
    /// `xt_w_subset` / `col_norms`.
    fn for_col_chunks(&self, out: &mut [f64], f: impl Fn(usize, &mut [f64], bool) + Sync) {
        let pool_threads = self.pool().threads();
        if pool_threads <= 1 || out.len() < PAR_MIN_COLS {
            f(0, out, false);
            return;
        }
        let chunk = pool::chunk_len(out.len(), pool_threads);
        let f = &f; // shared by every job (jobs only borrow)
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        let mut base = 0usize;
        for head in out.chunks_mut(chunk) {
            let start = base;
            base += head.len();
            jobs.push(Box::new(move || f(start, head, true)));
        }
        self.pool().run(jobs);
    }

    /// Compute column ℓ2 norms for `out.len()` columns starting at `base`
    /// (the same shard-order fold as `CscMatrix::col_norms`, so the sums —
    /// and their square roots — are bit-identical). Shards-outer like
    /// `sweep_cols_into`; every sqrt still happens after its column's fold
    /// is complete across all shards.
    fn norms_cols_into(&self, base: usize, out: &mut [f64], private_windows: bool) {
        let owned: Vec<Option<ShardBackend>> = if private_windows {
            self.shards.iter().map(|s| s.backend.private_window_clone()).collect()
        } else {
            self.shards.iter().map(|_| None).collect()
        };
        out.fill(0.0);
        for (s, ow) in self.shards.iter().zip(owned.iter()) {
            ow.as_ref().unwrap_or(&s.backend).fold_cols_sq_norm(base, out);
        }
        for o in out.iter_mut() {
            *o = o.sqrt();
        }
    }
}

/// Either a contiguous column range starting at `base`, or an explicit
/// column list (subset sweeps).
#[derive(Clone, Copy)]
enum ColBlock<'a> {
    Range(usize),
    List(&'a [usize]),
}

impl ColBlock<'_> {
    #[inline]
    fn get(&self, k: usize) -> usize {
        match self {
            ColBlock::Range(base) => base + k,
            ColBlock::List(cols) => cols[k],
        }
    }
}

impl DesignMatrix for ShardSetMatrix {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        // disjoint column blocks, one job each; the fold inside each block
        // is the bit-exact shard-order reduction
        self.for_col_chunks(out, |base, chunk, private| {
            self.sweep_cols_into(ColBlock::Range(base), w, chunk, private)
        });
    }

    fn col_dot_w(&self, j: usize, w: &[f64]) -> f64 {
        self.fold_full_col_dot(j, w)
    }

    fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]) {
        assert_eq!(out.len(), self.n_rows);
        for (s, win) in self.shards.iter().zip(self.row_starts.windows(2)) {
            s.backend.col_axpy_into(j, a, &mut out[win[0]..win[1]]);
        }
    }

    fn col_sq_norm(&self, j: usize) -> f64 {
        let mut acc = 0.0;
        for s in &self.shards {
            s.backend.fold_col_sq_norm(j, &mut acc);
        }
        acc
    }

    fn col_dot_col(&self, i: usize, j: usize) -> f64 {
        let mut acc = 0.0;
        for s in &self.shards {
            s.backend.fold_col_dot_col(i, j, &mut acc);
        }
        acc
    }

    fn col_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.n_rows);
        for (s, win) in self.shards.iter().zip(self.row_starts.windows(2)) {
            s.backend.col_into(j, &mut out[win[0]..win[1]]);
        }
    }

    fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]) {
        assert_eq!(rows.len(), out.len());
        out.fill(0.0);
        // group requested rows by owning shard → one backend gather each
        let mut positions: Vec<usize> = Vec::new();
        let mut local: Vec<usize> = Vec::new();
        let mut buf: Vec<f64> = Vec::new();
        for (s, win) in self.shards.iter().zip(self.row_starts.windows(2)) {
            positions.clear();
            local.clear();
            for (k, &r) in rows.iter().enumerate() {
                if r >= win[0] && r < win[1] {
                    positions.push(k);
                    local.push(r - win[0]);
                }
            }
            if positions.is_empty() {
                continue;
            }
            buf.clear();
            buf.resize(positions.len(), 0.0);
            s.backend.col_gather(j, &local, &mut buf);
            for (k, v) in positions.iter().zip(buf.iter()) {
                out[*k] = *v;
            }
        }
    }

    fn nnz(&self) -> usize {
        self.nnz
    }

    fn col_norms(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n_cols];
        self.for_col_chunks(&mut out, |base, chunk, private| {
            self.norms_cols_into(base, chunk, private)
        });
        out
    }

    fn xt_w_subset(&self, cols: &[usize], w: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), cols.len());
        self.for_col_chunks(out, |base, chunk, private| {
            self.sweep_cols_into(ColBlock::List(&cols[base..base + chunk.len()]), w, chunk, private)
        });
    }

    fn accum_cols(&self, cols: &[usize], beta: &[f64], out: &mut [f64]) {
        assert_eq!(cols.len(), beta.len());
        assert_eq!(out.len(), self.n_rows);
        // row ranges are disjoint → one job per shard, each accumulating
        // columns in caller order over its own slice (same per-element op
        // order as flat CSC)
        self.for_row_slices(out, |backend, out_local| {
            for (k, &j) in cols.iter().enumerate() {
                if beta[k] != 0.0 {
                    backend.col_axpy_into(j, beta[k], out_local);
                }
            }
        });
    }

    fn gemv(&self, beta: &[f64], out: &mut [f64]) {
        assert_eq!(beta.len(), self.n_cols);
        assert_eq!(out.len(), self.n_rows);
        self.for_row_slices(out, |backend, out_local| {
            out_local.fill(0.0);
            for (j, &b) in beta.iter().enumerate() {
                if b != 0.0 {
                    backend.col_axpy_into(j, b, out_local);
                }
            }
        });
    }
}

/// One manifest entry of `shardset.txt`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    /// Shard directory name, relative to the shard-set directory.
    pub dir: String,
    pub row_offset: usize,
    pub n_rows: usize,
    pub nnz: usize,
}

/// Parsed `shardset.txt` manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardSetMeta {
    pub n_rows: usize,
    pub n_cols: usize,
    pub nnz: usize,
    /// Shards in row order.
    pub shards: Vec<ShardEntry>,
}

/// Parse `<dir>/shardset.txt` (format documented in DESIGN.md §2c; written
/// by `data::convert::split_shard`).
pub fn read_shardset_meta(dir: &Path) -> Result<ShardSetMeta> {
    let path = dir.join(SHARDSET_FILE);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading shard-set manifest {path:?}"))?;
    let mut format = None;
    let mut version = None;
    let mut n_rows = None;
    let mut n_cols = None;
    let mut nnz = None;
    let mut declared = None;
    let mut shards: Vec<ShardEntry> = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("bad manifest line `{line}` in {path:?}");
        };
        let v = v.trim();
        match k.trim() {
            "format" => format = Some(v.to_string()),
            "version" => version = Some(v.to_string()),
            "n_rows" => n_rows = Some(v.parse::<usize>().context("bad n_rows")?),
            "n_cols" => n_cols = Some(v.parse::<usize>().context("bad n_cols")?),
            "nnz" => nnz = Some(v.parse::<usize>().context("bad nnz")?),
            "shards" => declared = Some(v.parse::<usize>().context("bad shards")?),
            "shard" => {
                let parts: Vec<&str> = v.split(':').collect();
                if parts.len() != 4 {
                    bail!("bad shard line `{line}` (dir:row_offset:n_rows:nnz)");
                }
                shards.push(ShardEntry {
                    dir: parts[0].to_string(),
                    row_offset: parts[1].parse().context("bad shard row_offset")?,
                    n_rows: parts[2].parse().context("bad shard n_rows")?,
                    nnz: parts[3].parse().context("bad shard nnz")?,
                });
            }
            _ => {} // forward-compatible
        }
    }
    match format.as_deref() {
        Some("dppshardset") => {}
        other => bail!("{path:?} is not a dppshardset manifest (format={other:?})"),
    }
    match version.as_deref() {
        Some("1") => {}
        other => bail!("unsupported dppshardset version {other:?}"),
    }
    let (Some(n_rows), Some(n_cols), Some(nnz)) = (n_rows, n_cols, nnz) else {
        bail!("{path:?} missing n_rows/n_cols/nnz");
    };
    if shards.is_empty() {
        bail!("{path:?} lists no shards");
    }
    if let Some(d) = declared {
        if d != shards.len() {
            bail!("{path:?} declares {d} shards but lists {}", shards.len());
        }
    }
    Ok(ShardSetMeta { n_rows, n_cols, nnz, shards })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;
    use crate::util::{prop, rng::Rng};

    fn random_csc(n: usize, p: usize, density: f64, seed: u64) -> CscMatrix {
        let mut rng = Rng::new(seed);
        let mut x = DenseMatrix::zeros(n, p);
        for j in 0..p {
            for v in x.col_mut(j).iter_mut() {
                if rng.f64() < density {
                    *v = rng.normal();
                }
            }
        }
        CscMatrix::from_dense(&x)
    }

    #[test]
    fn row_splits_cover_and_allow_empty() {
        assert_eq!(row_splits(10, 3), vec![0, 3, 6, 10]);
        assert_eq!(row_splits(2, 3), vec![0, 0, 1, 2]); // leading empty shard
        assert_eq!(row_splits(5, 1), vec![0, 5]);
    }

    /// The decisive property: every trait method on the sharded facade is
    /// **bit-identical** to the in-RAM CSC over the concatenated rows —
    /// the shard-order fold replays CSC's accumulation sequence exactly.
    #[test]
    fn sharded_matches_csc_bitwise_on_all_ops() {
        prop::check("DesignMatrix sharded == csc (bitwise)", 0x5AAD, 8, |rng| {
            let n = 3 + rng.usize(30);
            let p = 2 + rng.usize(40);
            let csc = random_csc(n, p, rng.uniform(0.1, 0.8), rng.next_u64());
            let k = 1 + rng.usize(4);
            let sh = ShardSetMatrix::split_csc(&csc, k);
            let c: &dyn DesignMatrix = &csc;
            let s: &dyn DesignMatrix = &sh;
            assert_eq!((c.n_rows(), c.n_cols(), c.nnz()), (s.n_rows(), s.n_cols(), s.nnz()));

            let mut w = vec![0.0; n];
            rng.fill_normal(&mut w);
            let mut a = vec![0.0; p];
            let mut b = vec![0.0; p];
            c.xt_w(&w, &mut a);
            s.xt_w(&w, &mut b);
            assert_eq!(a, b, "xt_w");
            assert_eq!(c.col_norms(), s.col_norms(), "col_norms");
            for j in 0..p {
                assert_eq!(c.col_dot_w(j, &w), s.col_dot_w(j, &w), "col_dot_w {j}");
                assert_eq!(c.col_sq_norm(j), s.col_sq_norm(j), "col_sq_norm {j}");
            }
            let i = rng.usize(p);
            let j = rng.usize(p);
            assert_eq!(c.col_dot_col(i, j), s.col_dot_col(i, j), "col_dot_col");

            let mut ca = vec![0.5; n];
            let mut sa = vec![0.5; n];
            c.col_axpy_into(j, -1.25, &mut ca);
            s.col_axpy_into(j, -1.25, &mut sa);
            assert_eq!(ca, sa, "col_axpy_into");

            let mut ci = vec![1.0; n];
            let mut si = vec![1.0; n];
            c.col_into(j, &mut ci);
            s.col_into(j, &mut si);
            assert_eq!(ci, si, "col_into");

            let rows: Vec<usize> = (0..n).rev().step_by(2).collect();
            let mut cg = vec![9.0; rows.len()];
            let mut sg = vec![9.0; rows.len()];
            c.col_gather(j, &rows, &mut cg);
            s.col_gather(j, &rows, &mut sg);
            assert_eq!(cg, sg, "col_gather");

            let mut beta = vec![0.0; p];
            rng.fill_normal(&mut beta);
            let mut cm = vec![0.0; n];
            let mut sm = vec![0.0; n];
            c.gemv(&beta, &mut cm);
            s.gemv(&beta, &mut sm);
            assert_eq!(cm, sm, "gemv");

            let cols: Vec<usize> = (0..p).step_by(2).collect();
            let mut cs = vec![0.0; cols.len()];
            let mut ss = vec![0.0; cols.len()];
            c.xt_w_subset(&cols, &w, &mut cs);
            s.xt_w_subset(&cols, &w, &mut ss);
            assert_eq!(cs, ss, "xt_w_subset");

            let red: Vec<f64> = cols.iter().map(|&j| beta[j]).collect();
            let mut cr = vec![0.1; n];
            let mut sr = vec![0.1; n];
            c.accum_cols(&cols, &red, &mut cr);
            s.accum_cols(&cols, &red, &mut sr);
            assert_eq!(cr, sr, "accum_cols");
        });
    }

    #[test]
    fn thread_count_never_changes_results() {
        let csc = random_csc(40, 256, 0.2, 42);
        let sh1 = ShardSetMatrix::split_csc(&csc, 3).with_pool(Arc::new(WorkerPool::new(1)));
        let sh4 = ShardSetMatrix::split_csc(&csc, 3).with_pool(Arc::new(WorkerPool::new(4)));
        let mut w = vec![0.0; 40];
        Rng::new(7).fill_normal(&mut w);
        let mut a = vec![0.0; 256];
        let mut b = vec![0.0; 256];
        sh1.xt_w(&w, &mut a);
        sh4.xt_w(&w, &mut b);
        assert_eq!(a, b);
        assert_eq!(DesignMatrix::col_norms(&sh1), DesignMatrix::col_norms(&sh4));
        let mut beta = vec![0.0; 256];
        Rng::new(8).fill_normal(&mut beta);
        let mut ga = vec![0.0; 40];
        let mut gb = vec![0.0; 40];
        sh1.gemv(&beta, &mut ga);
        sh4.gemv(&beta, &mut gb);
        assert_eq!(ga, gb);
    }

    #[test]
    fn boundary_through_dense_rows_and_empty_shards() {
        // a fully dense matrix: every boundary cuts through "dense rows";
        // plus explicit empty shards at both ends and in the middle
        let mut rng = Rng::new(3);
        let mut x = DenseMatrix::zeros(9, 7);
        for j in 0..7 {
            rng.fill_normal(x.col_mut(j));
        }
        let csc = CscMatrix::from_dense(&x);
        let sh = ShardSetMatrix::split_csc_at(&csc, &[0, 0, 4, 4, 9, 9]);
        assert_eq!(sh.shard_count(), 5);
        assert_eq!(sh.to_csc(), csc);
        let mut w = vec![0.0; 9];
        rng.fill_normal(&mut w);
        let mut a = vec![0.0; 7];
        let mut b = vec![0.0; 7];
        DesignMatrix::xt_w(&csc, &w, &mut a);
        DesignMatrix::xt_w(&sh, &w, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn round_trips_to_csc() {
        let csc = random_csc(23, 17, 0.35, 9);
        for k in [1, 2, 3, 5, 40] {
            let sh = ShardSetMatrix::split_csc(&csc, k);
            assert_eq!(sh.to_csc(), csc, "k={k}");
            assert_eq!(sh.clone().to_csc(), csc, "clone k={k}");
        }
    }

    #[test]
    fn manifest_parse_rejects_bad_input() {
        let dir = std::env::temp_dir().join("dpp-shardset-meta-test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(read_shardset_meta(&dir).is_err(), "missing manifest");
        let write = |text: &str| std::fs::write(dir.join(SHARDSET_FILE), text).unwrap();
        write("format=dppshardset\nversion=1\nn_rows=4\nn_cols=2\nnnz=3\nshards=1\nshard=s0:0:4:3\n");
        let m = read_shardset_meta(&dir).unwrap();
        assert_eq!(m.shards.len(), 1);
        assert_eq!(m.shards[0], ShardEntry { dir: "s0".into(), row_offset: 0, n_rows: 4, nnz: 3 });
        write("format=wrong\nversion=1\nn_rows=1\nn_cols=1\nnnz=0\nshard=s0:0:1:0\n");
        assert!(read_shardset_meta(&dir).is_err(), "wrong format");
        write("format=dppshardset\nversion=1\nn_rows=1\nn_cols=1\nnnz=0\nshards=2\nshard=s0:0:1:0\n");
        assert!(read_shardset_meta(&dir).is_err(), "shard count mismatch");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
