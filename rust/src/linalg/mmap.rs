//! Out-of-core CSC backend: the paper's §1 motivation ("we may not even be
//! able to load the data matrix into main memory") at full scale.
//!
//! [`MmapCscMatrix`] implements the complete [`DesignMatrix`] contract over
//! an on-disk **shard**: a directory holding the raw CSC triple
//! (`col_ptr.bin` / `row_idx.bin` / `values.bin`, little-endian) plus a
//! small `meta.txt` header and optionally the response `y.bin`
//! (DESIGN.md §2b documents the byte layout; `data::convert` writes it
//! in one bounded-memory pass from LIBSVM/CSV input).
//!
//! Only `col_ptr` (8·(p+1) bytes) and one sliding **window** of the entry
//! arrays are ever resident; the window is bounded by a configurable byte
//! budget (`open_with_budget`, or the `DPP_MMAP_BUDGET` env var), so the
//! peak footprint is independent of nnz. Every column-local kernel streams
//! its entries through the window in index order, which keeps the floating
//! point accumulation order identical to [`CscMatrix`] — the parity tests
//! in `rust/tests/backend_parity.rs` pin keep-sets and CD trajectories
//! bit-identical between the two sparse backends.
//!
//! The offline build image has no mmap-capable dependency (only `anyhow`
//! and the `xla` closure are vendored, DESIGN.md §6) and `std` exposes no
//! `mmap(2)` wrapper, so the window is filled with positioned
//! `read_exact_at` calls; the OS page cache plays the role of the mapped
//! pages. The behavioural contract is the same: X itself is never held in
//! process memory.

use std::fs::File;
use std::io::Read;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::{CscMatrix, DesignMatrix};

/// Shard file names (all inside the shard directory).
pub const META_FILE: &str = "meta.txt";
pub const COL_PTR_FILE: &str = "col_ptr.bin";
pub const ROW_IDX_FILE: &str = "row_idx.bin";
pub const VALUES_FILE: &str = "values.bin";
pub const Y_FILE: &str = "y.bin";

/// Bytes of resident window per stored entry (u32 row index + f64 value).
/// The decoded window always holds f64 values, so this is the resident cost
/// even for an f32 shard (whose *disk/IO* cost per entry is 8 bytes).
pub const ENTRY_BYTES: usize = 12;

/// On-disk bytes per entry for an f32 shard (u32 row index + f32 value).
pub const ENTRY_BYTES_F32: usize = 8;

/// Default window budget: 4 MiB ≈ 350k entries per refill.
pub const DEFAULT_WINDOW_BYTES: usize = 4 << 20;

/// Env var overriding the default window budget (bytes).
pub const BUDGET_ENV: &str = "DPP_MMAP_BUDGET";

/// Window-budget resolution shared by every opener (single shards and
/// shard sets): `DPP_MMAP_BUDGET` if set and parseable, else
/// [`DEFAULT_WINDOW_BYTES`].
pub fn default_budget() -> usize {
    std::env::var(BUDGET_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(DEFAULT_WINDOW_BYTES)
}

/// Sliding decoded window over the entry arrays: entries
/// `[start, start + idx.len())` of `row_idx.bin` / `values.bin`.
struct Pager {
    idx_file: File,
    val_file: File,
    start: usize,
    idx: Vec<u32>,
    vals: Vec<f64>,
    raw: Vec<u8>,
    /// Max entries per window (≥ 1).
    cap: usize,
    /// `values.bin` stores f32 (meta `dtype=f32`); widened to f64 on read.
    f32_values: bool,
}

impl Pager {
    /// Ensure entry `lo` is inside the window, refilling forward from `lo`
    /// (up to `cap` entries) if not. `total` is the shard's nnz.
    fn ensure(&mut self, lo: usize, total: usize) {
        if lo >= self.start && lo < self.start + self.idx.len() {
            return;
        }
        let end = total.min(lo + self.cap);
        let len = end - lo;
        self.raw.resize(len * 4, 0);
        self.idx_file
            .read_exact_at(&mut self.raw, (lo * 4) as u64)
            .expect("shard row_idx.bin read failed");
        self.idx.clear();
        self.idx.extend(
            self.raw.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        let vb = if self.f32_values { 4 } else { 8 };
        self.raw.resize(len * vb, 0);
        self.val_file
            .read_exact_at(&mut self.raw, (lo * vb) as u64)
            .expect("shard values.bin read failed");
        self.vals.clear();
        if self.f32_values {
            self.vals.extend(
                self.raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64),
            );
        } else {
            self.vals.extend(self.raw.chunks_exact(8).map(|c| {
                f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
            }));
        }
        // drop the byte scratch between refills: resident memory stays at
        // the documented 12 B/entry (idx + vals), not 20 B/entry — the
        // re-allocation per refill is noise next to the disk read itself
        self.raw = Vec::new();
        self.start = lo;
    }
}

/// Out-of-core CSC matrix paging `row_idx`/`values` from an on-disk shard.
///
/// One matrix owns **one** sliding window behind a `Mutex`, which makes it
/// `Sync` but serializes concurrent sweeps and lets threads at distant
/// offsets evict each other's window. For parallel workloads
/// (`stability_selection` rounds, multi-threaded trials), give each worker
/// its own handle via [`Clone`] — cloning reopens the shard with an
/// independent window, so readers never contend or thrash.
pub struct MmapCscMatrix {
    dir: PathBuf,
    n_rows: usize,
    n_cols: usize,
    nnz: usize,
    col_ptr: Vec<u64>,
    budget: usize,
    /// meta `dtype=f32`: values stored half-width, widened to f64 on read.
    /// Consumers screening on such a shard must widen keep-decisions by a
    /// safety slack (`ScreenContext::with_sweep_slack`, DESIGN.md §1) —
    /// the CLI wires this up via `PathConfig::safety_slack`.
    f32_values: bool,
    pager: Mutex<Pager>,
}

impl MmapCscMatrix {
    /// Open a shard directory with the default window budget
    /// (`DPP_MMAP_BUDGET` bytes if set, else [`DEFAULT_WINDOW_BYTES`]).
    pub fn open(dir: impl AsRef<Path>) -> Result<MmapCscMatrix> {
        Self::open_with_budget(dir, default_budget())
    }

    /// Open a shard directory, holding at most ~`budget_bytes` of decoded
    /// entries resident at a time (plus the 8·(p+1)-byte `col_ptr`).
    pub fn open_with_budget(dir: impl AsRef<Path>, budget_bytes: usize) -> Result<MmapCscMatrix> {
        let dir = dir.as_ref().to_path_buf();
        let meta = read_meta(&dir.join(META_FILE))
            .with_context(|| format!("reading shard meta {:?}", dir.join(META_FILE)))?;
        let ShardMeta { n_rows, n_cols, nnz, f32_values } = meta;
        if n_rows > u32::MAX as usize {
            bail!("shard n_rows {} exceeds u32 row-index range", n_rows);
        }

        let mut col_ptr = vec![0u64; n_cols + 1];
        {
            let mut f = File::open(dir.join(COL_PTR_FILE))
                .with_context(|| format!("opening {:?}", dir.join(COL_PTR_FILE)))?;
            let mut raw = vec![0u8; (n_cols + 1) * 8];
            f.read_exact(&mut raw).context("col_ptr.bin shorter than meta n_cols")?;
            for (dst, c) in col_ptr.iter_mut().zip(raw.chunks_exact(8)) {
                *dst = u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]);
            }
        }
        if col_ptr[0] != 0 {
            bail!("shard col_ptr[0] = {} (expected 0)", col_ptr[0]);
        }
        for j in 0..n_cols {
            if col_ptr[j] > col_ptr[j + 1] {
                bail!("shard col_ptr decreases at column {j}");
            }
        }
        if col_ptr[n_cols] != nnz as u64 {
            bail!("shard col_ptr end {} != meta nnz {}", col_ptr[n_cols], nnz);
        }

        let idx_file = File::open(dir.join(ROW_IDX_FILE))
            .with_context(|| format!("opening {:?}", dir.join(ROW_IDX_FILE)))?;
        let val_file = File::open(dir.join(VALUES_FILE))
            .with_context(|| format!("opening {:?}", dir.join(VALUES_FILE)))?;
        let idx_len = idx_file.metadata()?.len();
        let val_len = val_file.metadata()?.len();
        if idx_len != (nnz * 4) as u64 {
            bail!("row_idx.bin is {} bytes, expected {} (nnz {})", idx_len, nnz * 4, nnz);
        }
        let vb = if f32_values { 4 } else { 8 };
        if val_len != (nnz * vb) as u64 {
            bail!(
                "values.bin is {} bytes, expected {} (nnz {}, dtype {})",
                val_len,
                nnz * vb,
                nnz,
                if f32_values { "f32" } else { "f64" }
            );
        }

        let cap = (budget_bytes / ENTRY_BYTES).max(1);
        Ok(MmapCscMatrix {
            dir,
            n_rows,
            n_cols,
            nnz,
            col_ptr,
            budget: budget_bytes,
            f32_values,
            pager: Mutex::new(Pager {
                idx_file,
                val_file,
                start: 0,
                idx: Vec::new(),
                vals: Vec::new(),
                raw: Vec::new(),
                cap,
                f32_values,
            }),
        })
    }

    /// The shard directory this matrix pages from.
    pub fn shard_dir(&self) -> &Path {
        &self.dir
    }

    /// Configured window budget in bytes.
    pub fn window_budget(&self) -> usize {
        self.budget
    }

    /// Whether `values.bin` stores f32 (half the on-disk/IO traffic; values
    /// are widened to f64 in the window). Screening over f32-quantized data
    /// should widen keep-decisions by a safety slack — see DESIGN.md §1.
    pub fn is_f32(&self) -> bool {
        self.f32_values
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }
    /// Stored non-zeros (on disk, not resident).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Stream column `j`'s `(row_idx, values)` entries through the window
    /// in row order, invoking `f` once per resident chunk. The window lock
    /// is held across the call — `f` must not touch this matrix.
    pub fn for_col(&self, j: usize, mut f: impl FnMut(&[u32], &[f64])) {
        let lo = self.col_ptr[j] as usize;
        let hi = self.col_ptr[j + 1] as usize;
        let mut pager = self.pager.lock().unwrap();
        let mut s = lo;
        while s < hi {
            pager.ensure(s, self.nnz);
            let off = s - pager.start;
            let end = (pager.start + pager.idx.len()).min(hi);
            let n = end - s;
            f(&pager.idx[off..off + n], &pager.vals[off..off + n]);
            s = end;
        }
    }

    /// Copy one column's entries into owned buffers (bounded by the
    /// column's nnz — used only for merge-joins, never whole-matrix).
    fn materialize_col(&self, j: usize) -> (Vec<u32>, Vec<f64>) {
        let len = (self.col_ptr[j + 1] - self.col_ptr[j]) as usize;
        let mut idx = Vec::with_capacity(len);
        let mut vals = Vec::with_capacity(len);
        self.for_col(j, |i, v| {
            idx.extend_from_slice(i);
            vals.extend_from_slice(v);
        });
        (idx, vals)
    }

    /// Load the whole shard into an in-RAM [`CscMatrix`] (small problems,
    /// `--matrix csc` on a shard input, tests).
    pub fn to_csc(&self) -> CscMatrix {
        let mut col_ptr = Vec::with_capacity(self.n_cols + 1);
        col_ptr.push(0usize);
        let mut row_idx = Vec::with_capacity(self.nnz);
        let mut values = Vec::with_capacity(self.nnz);
        for j in 0..self.n_cols {
            self.for_col(j, |i, v| {
                row_idx.extend_from_slice(i);
                values.extend_from_slice(v);
            });
            col_ptr.push(values.len());
        }
        CscMatrix::from_parts(self.n_rows, self.n_cols, col_ptr, row_idx, values)
    }
}

impl Clone for MmapCscMatrix {
    fn clone(&self) -> MmapCscMatrix {
        MmapCscMatrix::open_with_budget(&self.dir, self.budget)
            .expect("reopening shard for clone")
    }
}

impl std::fmt::Debug for MmapCscMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapCscMatrix")
            .field("dir", &self.dir)
            .field("n_rows", &self.n_rows)
            .field("n_cols", &self.n_cols)
            .field("nnz", &self.nnz)
            .field("budget", &self.budget)
            .finish()
    }
}

impl DesignMatrix for MmapCscMatrix {
    fn n_rows(&self) -> usize {
        self.n_rows
    }

    fn n_cols(&self) -> usize {
        self.n_cols
    }

    fn xt_w(&self, w: &[f64], out: &mut [f64]) {
        assert_eq!(w.len(), self.n_rows);
        assert_eq!(out.len(), self.n_cols);
        // consecutive columns are consecutive in entry space, so the sweep
        // streams each window exactly once
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.col_dot_w(j, w);
        }
    }

    fn col_dot_w(&self, j: usize, w: &[f64]) -> f64 {
        let mut s = 0.0;
        self.for_col(j, |idx, vals| {
            for (i, v) in idx.iter().zip(vals.iter()) {
                s += w[*i as usize] * v;
            }
        });
        s
    }

    fn col_axpy_into(&self, j: usize, a: f64, out: &mut [f64]) {
        self.for_col(j, |idx, vals| {
            for (i, v) in idx.iter().zip(vals.iter()) {
                out[*i as usize] += a * v;
            }
        });
    }

    fn col_sq_norm(&self, j: usize) -> f64 {
        let mut s = 0.0;
        self.for_col(j, |_, vals| {
            for v in vals {
                s += v * v;
            }
        });
        s
    }

    fn col_dot_col(&self, i: usize, j: usize) -> f64 {
        // merge-join: column i materialized (bounded by its nnz), column j
        // streamed through the window
        let (ai, av) = self.materialize_col(i);
        let mut a = 0usize;
        let mut s = 0.0;
        self.for_col(j, |bi, bv| {
            for (b, v) in bi.iter().zip(bv.iter()) {
                while a < ai.len() && ai[a] < *b {
                    a += 1;
                }
                if a < ai.len() && ai[a] == *b {
                    s += av[a] * v;
                }
            }
        });
        s
    }

    fn col_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.n_rows);
        out.fill(0.0);
        self.for_col(j, |idx, vals| {
            for (i, v) in idx.iter().zip(vals.iter()) {
                out[*i as usize] = *v;
            }
        });
    }

    fn col_gather(&self, j: usize, rows: &[usize], out: &mut [f64]) {
        assert_eq!(rows.len(), out.len());
        // requested rows sorted once, then one forward merge against the
        // streamed column (rows need not be sorted or unique)
        let mut order: Vec<(u32, usize)> =
            rows.iter().enumerate().map(|(k, &r)| (r as u32, k)).collect();
        order.sort_unstable();
        out.fill(0.0);
        let mut pos = 0usize;
        self.for_col(j, |idx, vals| {
            for (i, v) in idx.iter().zip(vals.iter()) {
                while pos < order.len() && order[pos].0 < *i {
                    pos += 1;
                }
                let mut q = pos;
                while q < order.len() && order[q].0 == *i {
                    out[order[q].1] = *v;
                    q += 1;
                }
            }
        });
    }

    fn nnz(&self) -> usize {
        self.nnz
    }
}

/// Parsed `meta.txt` header of one `dppcsc` shard.
struct ShardMeta {
    n_rows: usize,
    n_cols: usize,
    nnz: usize,
    f32_values: bool,
}

/// Parse `meta.txt`.
fn read_meta(path: &Path) -> Result<ShardMeta> {
    let text = std::fs::read_to_string(path)?;
    let mut format = None;
    let mut version = None;
    let mut n_rows = None;
    let mut n_cols = None;
    let mut nnz = None;
    let mut f32_values = false;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("bad meta line `{line}`");
        };
        match k.trim() {
            "format" => format = Some(v.trim().to_string()),
            "version" => version = Some(v.trim().to_string()),
            "n_rows" => n_rows = Some(v.trim().parse::<usize>().context("bad n_rows")?),
            "n_cols" => n_cols = Some(v.trim().parse::<usize>().context("bad n_cols")?),
            "nnz" => nnz = Some(v.trim().parse::<usize>().context("bad nnz")?),
            "dtype" => match v.trim() {
                "f64" => f32_values = false,
                "f32" => f32_values = true,
                other => bail!("unsupported shard dtype `{other}` (f64|f32)"),
            },
            _ => {} // forward-compatible: ignore unknown keys (e.g. row_offset)
        }
    }
    match format.as_deref() {
        Some("dppcsc") => {}
        other => bail!("not a dppcsc shard (format={other:?})"),
    }
    match version.as_deref() {
        Some("1") => {}
        other => bail!("unsupported dppcsc version {other:?}"),
    }
    match (n_rows, n_cols, nnz) {
        (Some(n), Some(p), Some(z)) => {
            Ok(ShardMeta { n_rows: n, n_cols: p, nnz: z, f32_values })
        }
        _ => bail!("meta.txt missing n_rows/n_cols/nnz"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::convert::shard_from_design;
    use crate::linalg::DenseMatrix;
    use crate::util::{prop, rng::Rng};

    fn tmp_shard(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("dpp-mmap-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn random_csc(n: usize, p: usize, density: f64, seed: u64) -> CscMatrix {
        let mut rng = Rng::new(seed);
        let mut x = DenseMatrix::zeros(n, p);
        for j in 0..p {
            for v in x.col_mut(j).iter_mut() {
                if rng.f64() < density {
                    *v = rng.normal();
                }
            }
        }
        CscMatrix::from_dense(&x)
    }

    /// Every trait method must agree with the in-RAM CSC built from the
    /// same data, even when the window budget forces many refills — the
    /// out-of-core analogue of `design.rs::dense_and_csc_agree_on_all_ops`.
    #[test]
    fn mmap_matches_csc_on_all_ops_with_tiny_windows() {
        prop::check("DesignMatrix mmap == csc", 0x33A9, 6, |rng| {
            let n = 2 + rng.usize(20);
            let p = 2 + rng.usize(25);
            let csc = random_csc(n, p, rng.uniform(0.1, 0.8), rng.next_u64());
            let dir = tmp_shard(&format!("ops-{n}-{p}"));
            shard_from_design(&csc, None, &dir).unwrap();
            // budgets from one-entry windows up: correctness must not
            // depend on window placement
            let budget = [1, 60, 4096][rng.usize(3)];
            let mm = MmapCscMatrix::open_with_budget(&dir, budget).unwrap();
            let s: &dyn DesignMatrix = &csc;
            let m: &dyn DesignMatrix = &mm;
            assert_eq!((s.n_rows(), s.n_cols(), s.nnz()), (m.n_rows(), m.n_cols(), m.nnz()));

            let mut w = vec![0.0; n];
            rng.fill_normal(&mut w);
            let mut a = vec![0.0; p];
            let mut b = vec![0.0; p];
            s.xt_w(&w, &mut a);
            m.xt_w(&w, &mut b);
            // identical accumulation order ⇒ bit-identical, not just close
            assert_eq!(a, b, "xt_w");
            for j in 0..p {
                assert_eq!(s.col_dot_w(j, &w), m.col_dot_w(j, &w), "col_dot_w {j}");
                assert_eq!(s.col_sq_norm(j), m.col_sq_norm(j), "col_sq_norm {j}");
            }
            let i = rng.usize(p);
            let j = rng.usize(p);
            assert_eq!(s.col_dot_col(i, j), m.col_dot_col(i, j), "col_dot_col ({i},{j})");

            let mut sa = vec![0.0; n];
            let mut ma = vec![0.0; n];
            s.col_axpy_into(j, -2.5, &mut sa);
            m.col_axpy_into(j, -2.5, &mut ma);
            assert_eq!(sa, ma, "col_axpy_into {j}");

            let mut sc = vec![1.0; n];
            let mut mc = vec![1.0; n];
            s.col_into(j, &mut sc);
            m.col_into(j, &mut mc);
            assert_eq!(sc, mc, "col_into {j}");

            let rows: Vec<usize> = (0..n).rev().step_by(2).collect(); // unsorted on purpose
            let mut sr = vec![0.0; rows.len()];
            let mut mr = vec![0.0; rows.len()];
            s.col_gather(j, &rows, &mut sr);
            m.col_gather(j, &rows, &mut mr);
            assert_eq!(sr, mr, "col_gather {j}");
            let _ = std::fs::remove_dir_all(&dir);
        });
    }

    #[test]
    fn round_trips_through_to_csc() {
        let csc = random_csc(17, 23, 0.3, 5);
        let dir = tmp_shard("roundtrip");
        shard_from_design(&csc, None, &dir).unwrap();
        let mm = MmapCscMatrix::open_with_budget(&dir, 100).unwrap();
        assert_eq!(mm.to_csc(), csc);
        // clone reopens the shard and still agrees
        let cl = mm.clone();
        assert_eq!(cl.to_csc(), csc);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn window_stays_within_budget_while_data_exceeds_it() {
        // the acceptance-criterion shape: values+indices far larger than
        // the window budget, every op still exact
        let csc = random_csc(40, 120, 0.4, 6);
        let on_disk = csc.nnz() * ENTRY_BYTES;
        let budget = 256;
        assert!(on_disk > 4 * budget, "test problem too small: {on_disk} bytes");
        let dir = tmp_shard("budget");
        shard_from_design(&csc, None, &dir).unwrap();
        let mm = MmapCscMatrix::open_with_budget(&dir, budget).unwrap();
        {
            let pager = mm.pager.lock().unwrap();
            assert!(pager.cap * ENTRY_BYTES <= budget.max(ENTRY_BYTES));
        }
        let mut w = vec![0.0; 40];
        Rng::new(7).fill_normal(&mut w);
        let mut a = vec![0.0; 120];
        let mut b = vec![0.0; 120];
        csc.gemv_t(&w, &mut a);
        mm.xt_w(&w, &mut b);
        assert_eq!(a, b);
        // after a full sweep the resident window is still ≤ cap entries
        let pager = mm.pager.lock().unwrap();
        assert!(pager.idx.len() <= pager.cap);
    }

    #[test]
    fn open_rejects_missing_and_corrupt_shards() {
        assert!(MmapCscMatrix::open(tmp_shard("nope")).is_err());
        // corrupt: truncate values.bin after a valid write
        let csc = random_csc(8, 6, 0.5, 8);
        let dir = tmp_shard("corrupt");
        shard_from_design(&csc, None, &dir).unwrap();
        let vals = dir.join(VALUES_FILE);
        let f = std::fs::OpenOptions::new().write(true).open(&vals).unwrap();
        f.set_len(3).unwrap();
        let err = MmapCscMatrix::open_with_budget(&dir, 1024).unwrap_err();
        assert!(format!("{err:#}").contains("values.bin"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
