//! Group-Lasso pathwise driver (paper §4.2 protocol): solve along a λ-grid
//! below λ̄max with sequential group screening and warm starts. Like the
//! Lasso driver, it drives the stateful [`GroupScreener`] lifecycle — the
//! screener owns the group θ-propagation (DESIGN.md §3).

use super::StepRecord;
use crate::linalg::{nrm2, DesignMatrix};
use crate::screening::group_edpp::{GroupEdppRule, GroupScreenContext};
use crate::screening::group_strong::{
    group_kkt_sweep_scored, group_kkt_violations, GroupStrongRule,
};
use crate::screening::pipeline::{GroupRuleScreener, GroupScreener};
use crate::solver::{dual, group::GroupBcdSolver, SolveOptions};
use crate::util::timer::timed;

/// Group-screening rule selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupRuleKind {
    None,
    Edpp,
    Strong,
}

impl GroupRuleKind {
    pub fn name(&self) -> &'static str {
        match self {
            GroupRuleKind::None => "none",
            GroupRuleKind::Edpp => "group-edpp",
            GroupRuleKind::Strong => "group-strong",
        }
    }

    /// Instantiate the lifecycle screener for this rule.
    fn build(&self) -> GroupRuleScreener {
        match self {
            GroupRuleKind::None => GroupRuleScreener::none(),
            GroupRuleKind::Edpp => GroupRuleScreener::new(Box::new(GroupEdppRule)),
            GroupRuleKind::Strong => GroupRuleScreener::new(Box::new(GroupStrongRule)),
        }
    }
}

/// Output of a group path run (records are per λ; `discarded`/`true_zeros`
/// count *groups*).
#[derive(Clone, Debug)]
pub struct GroupPathOutput {
    pub rule: String,
    pub records: Vec<StepRecord>,
    pub betas: Vec<Vec<f64>>,
}

impl GroupPathOutput {
    pub fn mean_rejection_ratio(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        // audit:allow(determinism:float-sum, per-step summary ratio off the solve path)
        self.records.iter().map(|r| r.rejection_ratio()).sum::<f64>()
            / self.records.len() as f64
    }

    pub fn total_screen_secs(&self) -> f64 {
        self.records.iter().map(|r| r.screen_secs).sum()
    }

    pub fn total_solve_secs(&self) -> f64 {
        self.records.iter().map(|r| r.solve_secs).sum()
    }

    pub fn total_secs(&self) -> f64 {
        self.total_screen_secs() + self.total_solve_secs()
    }
}

/// Solve the group Lasso along `grid_fracs·λ̄max` with the given rule, on
/// any [`DesignMatrix`] backend.
pub fn solve_group_path(
    x: &dyn DesignMatrix,
    y: &[f64],
    groups: &[(usize, usize)],
    grid: &super::LambdaGrid,
    rule_kind: GroupRuleKind,
    opts: &SolveOptions,
) -> GroupPathOutput {
    let ctx = GroupScreenContext::new(x, y, groups);
    let mut screener = rule_kind.build();
    let n_groups = groups.len();
    let p = x.n_cols();

    let mut records = Vec::with_capacity(grid.values.len());
    let mut betas = Vec::with_capacity(grid.values.len());

    // the screener owns the group θ-propagation; the driver keeps only the
    // per-group warm starts
    screener.init(&ctx);
    let mut beta_prev: Vec<Vec<f64>> =
        groups.iter().map(|&(_, len)| vec![0.0; len]).collect();

    for &lam in &grid.values {
        if lam >= ctx.lam_max * (1.0 - 1e-12) {
            records.push(StepRecord {
                lam,
                kept: 0,
                discarded: n_groups,
                true_zeros: n_groups,
                screen_secs: 0.0,
                solve_secs: 0.0,
                solver_iters: 0,
                kkt_repairs: 0,
                gap: 0.0,
                stage_discards: Vec::new(),
                dynamic_discards: 0,
                working_set_size: 0,
                kkt_passes: 0,
            });
            betas.push(vec![0.0; p]);
            screener.init(&ctx);
            for b in beta_prev.iter_mut() {
                b.fill(0.0);
            }
            continue;
        }

        let mut keep = vec![true; n_groups];
        let (stage_discards, screen_secs) =
            timed(|| screener.screen_step(&ctx, lam, &mut keep));
        let kept0 = keep.iter().filter(|k| **k).count();

        let is_safe = screener.is_safe();
        let mut kkt_repairs = 0usize;
        let mut kkt_passes = 0usize;
        let mut result: Option<crate::solver::group::GroupSolveResult> = None;
        let (res, solve_secs) = timed(|| {
            loop {
                let active: Vec<usize> = (0..n_groups).filter(|&g| keep[g]).collect();
                let warm: Vec<Vec<f64>> =
                    active.iter().map(|&g| beta_prev[g].clone()).collect();
                result = Some(GroupBcdSolver.solve(
                    x,
                    y,
                    groups,
                    &active,
                    lam,
                    Some(&warm),
                    opts,
                ));
                if is_safe {
                    break;
                }
                let res = result.as_ref().unwrap();
                let full = res.scatter(groups, &active, p);
                let mut r = y.to_vec();
                for (j, b) in full.iter().enumerate() {
                    if *b != 0.0 {
                        x.col_axpy_into(j, -b, &mut r);
                    }
                }
                kkt_passes += 1;
                let viol = group_kkt_violations(&ctx, &r, lam, &keep);
                if viol.is_empty() {
                    break;
                }
                kkt_repairs += 1;
                for g in viol {
                    keep[g] = true;
                }
            }
            result.take().unwrap()
        });

        let active: Vec<usize> = (0..n_groups).filter(|&g| keep[g]).collect();
        let full = res.scatter(groups, &active, p);
        // per-group zero count on the full-length solution
        let true_zeros = groups
            .iter()
            .filter(|&&(start, len)| full[start..start + len].iter().all(|v| *v == 0.0))
            .count();
        let discarded = n_groups - active.len();

        records.push(StepRecord {
            lam,
            kept: kept0,
            discarded,
            true_zeros,
            screen_secs,
            solve_secs,
            solver_iters: res.iters,
            kkt_repairs,
            gap: res.gap,
            stage_discards,
            dynamic_discards: 0,
            working_set_size: active.len(),
            kkt_passes,
        });

        // advance the screener's sequential state; keep the warm starts
        screener.observe(&ctx, lam, &full);
        for (g, &(start, len)) in groups.iter().enumerate() {
            beta_prev[g].copy_from_slice(&full[start..start + len]);
        }
        betas.push(full);
    }

    GroupPathOutput { rule: screener.name(), records, betas }
}

/// Outer-loop safety valve for the group working-set driver (same rationale
/// as the Lasso engine's cap in [`crate::solver::working_set`]).
const WS_MAX_ROUNDS: usize = 64;

/// Active warm start for the group working-set path: the accumulated working
/// set of *groups* and the last certified full-length β. `Default` is the
/// cold start.
#[derive(Clone, Debug, Default)]
pub struct GroupWorkingSetState {
    /// Accumulated working set (group indices, ascending): the union of
    /// every group ever admitted across λ steps.
    pub active: Vec<usize>,
    /// Full-length β from the last solve (support ⊆ `active`'s columns).
    pub beta: Vec<f64>,
}

impl GroupWorkingSetState {
    /// Drop everything — the next solve is a cold start.
    pub fn reset(&mut self) {
        self.active.clear();
        self.beta.clear();
    }
}

/// Group working-set path driver: the group analogue of the Lasso engine in
/// [`crate::solver::working_set`]. Per λ, seed a working set of groups from
/// the screening survivors plus the accumulated active set, solve the
/// restricted group subproblem (BCD over W's groups) to a tightened inner
/// gap, then pay one sweep over all groups computing the ellipsoid ratios
/// `‖X_gᵀr‖/√n_g` ([`group_kkt_sweep_scored`]) — complement violators
/// (ratio > λ) join W in doubling batches, and the global max ratio prices
/// the **full-problem** group duality gap
/// ([`dual::duality_gap_from_parts`]). Certification is exact-to-tolerance
/// on the original problem, never heuristic, even from an empty or unsafe
/// seed.
pub fn solve_group_path_working_set(
    x: &dyn DesignMatrix,
    y: &[f64],
    groups: &[(usize, usize)],
    grid: &super::LambdaGrid,
    rule_kind: GroupRuleKind,
    opts: &SolveOptions,
) -> GroupPathOutput {
    let ctx = GroupScreenContext::new(x, y, groups);
    let mut screener = rule_kind.build();
    let n_groups = groups.len();
    let p = x.n_cols();

    let mut records = Vec::with_capacity(grid.values.len());
    let mut betas = Vec::with_capacity(grid.values.len());

    screener.init(&ctx);
    let mut state = GroupWorkingSetState::default();
    state.beta.resize(p, 0.0);

    for &lam in &grid.values {
        if lam >= ctx.lam_max * (1.0 - 1e-12) {
            records.push(StepRecord {
                lam,
                kept: 0,
                discarded: n_groups,
                true_zeros: n_groups,
                screen_secs: 0.0,
                solve_secs: 0.0,
                solver_iters: 0,
                kkt_repairs: 0,
                gap: 0.0,
                stage_discards: Vec::new(),
                dynamic_discards: 0,
                working_set_size: 0,
                kkt_passes: 0,
            });
            betas.push(vec![0.0; p]);
            screener.init(&ctx);
            // the accumulated working set is kept — it only seeds, never
            // constrains, the next λ's solve
            continue;
        }

        let mut keep = vec![true; n_groups];
        let (stage_discards, screen_secs) =
            timed(|| screener.screen_step(&ctx, lam, &mut keep));
        let kept0 = keep.iter().filter(|k| **k).count();

        // W₀ = screening survivors ∪ accumulated active groups
        let mut in_ws = keep;
        for &g in &state.active {
            in_ws[g] = true;
        }
        let mut ws: Vec<usize> = (0..n_groups).filter(|&g| in_ws[g]).collect();

        // tightened inner tolerance (same contract as the Lasso engine)
        let mut inner = opts.clone();
        inner.tol_gap = 0.5 * opts.tol_gap;

        let mut full = vec![0.0; p];
        let mut r = vec![0.0; y.len()];
        let mut iters = 0usize;
        let mut kkt_passes = 0usize;
        let mut expansions = 0usize;
        let mut gap = f64::INFINITY;
        let mut batch = 4usize;

        let ((), solve_secs) = timed(|| {
            for _round in 0..WS_MAX_ROUNDS {
                // ---- restricted group solve over W ----
                let mut budget_hit = false;
                if ws.is_empty() {
                    full.fill(0.0);
                    r.copy_from_slice(y);
                } else {
                    let warm: Vec<Vec<f64>> = ws
                        .iter()
                        .map(|&g| {
                            let (start, len) = groups[g];
                            state.beta[start..start + len].to_vec()
                        })
                        .collect();
                    let res = GroupBcdSolver
                        .solve(x, y, groups, &ws, lam, Some(&warm), &inner);
                    iters += res.iters;
                    budget_hit =
                        inner.time_budget.is_some() && res.gap > inner.tol_gap;
                    full = res.scatter(groups, &ws, p);
                    r.copy_from_slice(y);
                    for (j, b) in full.iter().enumerate() {
                        if *b != 0.0 {
                            x.col_axpy_into(j, -b, &mut r);
                        }
                    }
                }

                // ---- one shared sweep: ellipsoid ratios for every group ----
                let (viol, max_ratio) = group_kkt_sweep_scored(&ctx, &r, lam, &in_ws);
                kkt_passes += 1;
                let mut pen = 0.0;
                for &g in &ws {
                    let (start, len) = groups[g];
                    pen += (len as f64).sqrt() * nrm2(&full[start..start + len]);
                }
                gap = dual::duality_gap_from_parts(y, &r, pen, max_ratio, lam);
                if gap <= opts.tol_gap || budget_hit {
                    break;
                }
                if viol.is_empty() {
                    // complement clean: the gap is inner-solve slack
                    if inner.tol_gap <= 1e-15 {
                        break;
                    }
                    inner.tol_gap *= 0.25;
                    continue;
                }
                expansions += 1;
                for &(g, _) in viol.iter().take(batch) {
                    in_ws[g] = true;
                }
                batch = batch.saturating_mul(2);
                ws = (0..n_groups).filter(|&g| in_ws[g]).collect();
            }
        });

        // persist the active warm start (ws already contains the previous
        // state.active, so assigning it is the union)
        state.beta.copy_from_slice(&full);
        state.active = ws.clone();

        let true_zeros = groups
            .iter()
            .filter(|&&(start, len)| full[start..start + len].iter().all(|v| *v == 0.0))
            .count();

        records.push(StepRecord {
            lam,
            kept: kept0,
            discarded: n_groups - ws.len(),
            true_zeros,
            screen_secs,
            solve_secs,
            solver_iters: iters,
            kkt_repairs: expansions,
            gap,
            stage_discards,
            dynamic_discards: 0,
            working_set_size: ws.len(),
            kkt_passes,
        });

        screener.observe(&ctx, lam, &full);
        betas.push(full);
    }

    GroupPathOutput { rule: screener.name(), records, betas }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::path::LambdaGrid;
    use crate::solver::dual::group_lambda_max;

    fn setup(seed: u64) -> (crate::data::Dataset, Vec<(usize, usize)>, LambdaGrid) {
        let ds = synthetic::group_synthetic(30, 200, 40, seed);
        let groups = ds.groups.clone().unwrap();
        let (glm, _) = group_lambda_max(&ds.x, &ds.y, &groups);
        let grid = LambdaGrid::relative_to(glm, 8, 0.1, 1.0);
        (ds, groups, grid)
    }

    #[test]
    fn group_edpp_path_exact_vs_baseline() {
        let (ds, groups, grid) = setup(1);
        let opts = SolveOptions::default();
        let edpp =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
        let base =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::None, &opts);
        for (be, bb) in edpp.betas.iter().zip(base.betas.iter()) {
            for j in 0..ds.p() {
                assert!(
                    (be[j] - bb[j]).abs() < 5e-3 * (1.0 + bb[j].abs()),
                    "feature {j}: {} vs {}",
                    be[j],
                    bb[j]
                );
            }
        }
        assert!(edpp.mean_rejection_ratio() > 0.5);
        assert!(edpp.mean_rejection_ratio() <= 1.0 + 1e-12);
    }

    #[test]
    fn group_strong_with_repair_exact() {
        let (ds, groups, grid) = setup(2);
        let opts = SolveOptions::default();
        let strong =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Strong, &opts);
        let base =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::None, &opts);
        for (bs, bb) in strong.betas.iter().zip(base.betas.iter()) {
            for j in 0..ds.p() {
                assert!((bs[j] - bb[j]).abs() < 5e-3 * (1.0 + bb[j].abs()));
            }
        }
    }

    #[test]
    fn screened_path_is_faster_metricwise() {
        // not a wall-clock assertion (1-core CI variance) — check the
        // screening actually reduced solver work
        let (ds, groups, grid) = setup(3);
        let opts = SolveOptions::default();
        let edpp =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
        let total_kept: usize = edpp.records.iter().map(|r| r.kept).sum();
        let total_possible = groups.len() * edpp.records.len();
        assert!(total_kept * 2 < total_possible, "kept {total_kept}/{total_possible}");
    }

    #[test]
    fn working_set_group_path_exact_vs_baseline() {
        let (ds, groups, grid) = setup(4);
        let opts = SolveOptions::default();
        let ws = solve_group_path_working_set(
            &ds.x,
            &ds.y,
            &groups,
            &grid,
            GroupRuleKind::Strong,
            &opts,
        );
        let base =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::None, &opts);
        for (bw, bb) in ws.betas.iter().zip(base.betas.iter()) {
            for j in 0..ds.p() {
                assert!(
                    (bw[j] - bb[j]).abs() < 5e-3 * (1.0 + bb[j].abs()),
                    "feature {j}: {} vs {}",
                    bw[j],
                    bb[j]
                );
            }
        }
        // every non-trivial step is certified on the *full* problem and
        // actually restricted its solver work to a working set of groups
        for rec in ws.records.iter().filter(|r| r.kkt_passes > 0) {
            assert!(rec.gap <= opts.tol_gap, "λ={} gap {}", rec.lam, rec.gap);
            assert!(rec.working_set_size + rec.discarded == groups.len());
        }
        let restricted = ws
            .records
            .iter()
            .any(|r| r.kkt_passes > 0 && r.working_set_size < groups.len());
        assert!(restricted, "no step ran on a restricted group working set");
    }
}
