//! Group-Lasso pathwise driver (paper §4.2 protocol): solve along a λ-grid
//! below λ̄max with sequential group screening and warm starts. Like the
//! Lasso driver, it drives the stateful [`GroupScreener`] lifecycle — the
//! screener owns the group θ-propagation (DESIGN.md §3).

use super::StepRecord;
use crate::linalg::DesignMatrix;
use crate::screening::group_edpp::{GroupEdppRule, GroupScreenContext};
use crate::screening::group_strong::{group_kkt_violations, GroupStrongRule};
use crate::screening::pipeline::{GroupRuleScreener, GroupScreener};
use crate::solver::{group::GroupBcdSolver, SolveOptions};
use crate::util::timer::timed;

/// Group-screening rule selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GroupRuleKind {
    None,
    Edpp,
    Strong,
}

impl GroupRuleKind {
    pub fn name(&self) -> &'static str {
        match self {
            GroupRuleKind::None => "none",
            GroupRuleKind::Edpp => "group-edpp",
            GroupRuleKind::Strong => "group-strong",
        }
    }

    /// Instantiate the lifecycle screener for this rule.
    fn build(&self) -> GroupRuleScreener {
        match self {
            GroupRuleKind::None => GroupRuleScreener::none(),
            GroupRuleKind::Edpp => GroupRuleScreener::new(Box::new(GroupEdppRule)),
            GroupRuleKind::Strong => GroupRuleScreener::new(Box::new(GroupStrongRule)),
        }
    }
}

/// Output of a group path run (records are per λ; `discarded`/`true_zeros`
/// count *groups*).
#[derive(Clone, Debug)]
pub struct GroupPathOutput {
    pub rule: String,
    pub records: Vec<StepRecord>,
    pub betas: Vec<Vec<f64>>,
}

impl GroupPathOutput {
    pub fn mean_rejection_ratio(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        // audit:allow(determinism:float-sum, per-step summary ratio off the solve path)
        self.records.iter().map(|r| r.rejection_ratio()).sum::<f64>()
            / self.records.len() as f64
    }

    pub fn total_screen_secs(&self) -> f64 {
        self.records.iter().map(|r| r.screen_secs).sum()
    }

    pub fn total_solve_secs(&self) -> f64 {
        self.records.iter().map(|r| r.solve_secs).sum()
    }

    pub fn total_secs(&self) -> f64 {
        self.total_screen_secs() + self.total_solve_secs()
    }
}

/// Solve the group Lasso along `grid_fracs·λ̄max` with the given rule, on
/// any [`DesignMatrix`] backend.
pub fn solve_group_path(
    x: &dyn DesignMatrix,
    y: &[f64],
    groups: &[(usize, usize)],
    grid: &super::LambdaGrid,
    rule_kind: GroupRuleKind,
    opts: &SolveOptions,
) -> GroupPathOutput {
    let ctx = GroupScreenContext::new(x, y, groups);
    let mut screener = rule_kind.build();
    let n_groups = groups.len();
    let p = x.n_cols();

    let mut records = Vec::with_capacity(grid.values.len());
    let mut betas = Vec::with_capacity(grid.values.len());

    // the screener owns the group θ-propagation; the driver keeps only the
    // per-group warm starts
    screener.init(&ctx);
    let mut beta_prev: Vec<Vec<f64>> =
        groups.iter().map(|&(_, len)| vec![0.0; len]).collect();

    for &lam in &grid.values {
        if lam >= ctx.lam_max * (1.0 - 1e-12) {
            records.push(StepRecord {
                lam,
                kept: 0,
                discarded: n_groups,
                true_zeros: n_groups,
                screen_secs: 0.0,
                solve_secs: 0.0,
                solver_iters: 0,
                kkt_repairs: 0,
                gap: 0.0,
                stage_discards: Vec::new(),
                dynamic_discards: 0,
            });
            betas.push(vec![0.0; p]);
            screener.init(&ctx);
            for b in beta_prev.iter_mut() {
                b.fill(0.0);
            }
            continue;
        }

        let mut keep = vec![true; n_groups];
        let (stage_discards, screen_secs) =
            timed(|| screener.screen_step(&ctx, lam, &mut keep));
        let kept0 = keep.iter().filter(|k| **k).count();

        let is_safe = screener.is_safe();
        let mut kkt_repairs = 0usize;
        let mut result: Option<crate::solver::group::GroupSolveResult> = None;
        let (res, solve_secs) = timed(|| {
            loop {
                let active: Vec<usize> = (0..n_groups).filter(|&g| keep[g]).collect();
                let warm: Vec<Vec<f64>> =
                    active.iter().map(|&g| beta_prev[g].clone()).collect();
                result = Some(GroupBcdSolver.solve(
                    x,
                    y,
                    groups,
                    &active,
                    lam,
                    Some(&warm),
                    opts,
                ));
                if is_safe {
                    break;
                }
                let res = result.as_ref().unwrap();
                let full = res.scatter(groups, &active, p);
                let mut r = y.to_vec();
                for (j, b) in full.iter().enumerate() {
                    if *b != 0.0 {
                        x.col_axpy_into(j, -b, &mut r);
                    }
                }
                let viol = group_kkt_violations(&ctx, &r, lam, &keep);
                if viol.is_empty() {
                    break;
                }
                kkt_repairs += 1;
                for g in viol {
                    keep[g] = true;
                }
            }
            result.take().unwrap()
        });

        let active: Vec<usize> = (0..n_groups).filter(|&g| keep[g]).collect();
        let full = res.scatter(groups, &active, p);
        // per-group zero count on the full-length solution
        let true_zeros = groups
            .iter()
            .filter(|&&(start, len)| full[start..start + len].iter().all(|v| *v == 0.0))
            .count();
        let discarded = n_groups - active.len();

        records.push(StepRecord {
            lam,
            kept: kept0,
            discarded,
            true_zeros,
            screen_secs,
            solve_secs,
            solver_iters: res.iters,
            kkt_repairs,
            gap: res.gap,
            stage_discards,
            dynamic_discards: 0,
        });

        // advance the screener's sequential state; keep the warm starts
        screener.observe(&ctx, lam, &full);
        for (g, &(start, len)) in groups.iter().enumerate() {
            beta_prev[g].copy_from_slice(&full[start..start + len]);
        }
        betas.push(full);
    }

    GroupPathOutput { rule: screener.name(), records, betas }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::path::LambdaGrid;
    use crate::solver::dual::group_lambda_max;

    fn setup(seed: u64) -> (crate::data::Dataset, Vec<(usize, usize)>, LambdaGrid) {
        let ds = synthetic::group_synthetic(30, 200, 40, seed);
        let groups = ds.groups.clone().unwrap();
        let (glm, _) = group_lambda_max(&ds.x, &ds.y, &groups);
        let grid = LambdaGrid::relative_to(glm, 8, 0.1, 1.0);
        (ds, groups, grid)
    }

    #[test]
    fn group_edpp_path_exact_vs_baseline() {
        let (ds, groups, grid) = setup(1);
        let opts = SolveOptions::default();
        let edpp =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
        let base =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::None, &opts);
        for (be, bb) in edpp.betas.iter().zip(base.betas.iter()) {
            for j in 0..ds.p() {
                assert!(
                    (be[j] - bb[j]).abs() < 5e-3 * (1.0 + bb[j].abs()),
                    "feature {j}: {} vs {}",
                    be[j],
                    bb[j]
                );
            }
        }
        assert!(edpp.mean_rejection_ratio() > 0.5);
        assert!(edpp.mean_rejection_ratio() <= 1.0 + 1e-12);
    }

    #[test]
    fn group_strong_with_repair_exact() {
        let (ds, groups, grid) = setup(2);
        let opts = SolveOptions::default();
        let strong =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Strong, &opts);
        let base =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::None, &opts);
        for (bs, bb) in strong.betas.iter().zip(base.betas.iter()) {
            for j in 0..ds.p() {
                assert!((bs[j] - bb[j]).abs() < 5e-3 * (1.0 + bb[j].abs()));
            }
        }
    }

    #[test]
    fn screened_path_is_faster_metricwise() {
        // not a wall-clock assertion (1-core CI variance) — check the
        // screening actually reduced solver work
        let (ds, groups, grid) = setup(3);
        let opts = SolveOptions::default();
        let edpp =
            solve_group_path(&ds.x, &ds.y, &groups, &grid, GroupRuleKind::Edpp, &opts);
        let total_kept: usize = edpp.records.iter().map(|r| r.kept).sum();
        let total_possible = groups.len() * edpp.records.len();
        assert!(total_kept * 2 < total_possible, "kept {total_kept}/{total_possible}");
    }
}
