//! Stability selection (Meinshausen & Bühlmann) — the second model-selection
//! workload the paper's introduction names as a driver for sequential
//! screening ("commonly used approaches such as cross validation and
//! stability selection involve solving the Lasso problems over a grid of
//! tuning parameters", §1).
//!
//! B subsample rounds of ⌊N/2⌋ rows each; every round runs a full screened
//! λ-path; the output is, per feature, the maximum over λ of the fraction
//! of rounds in which the feature entered the support — the stability score
//! used to select features at a threshold (typically 0.6–0.9).

use super::{solve_path, LambdaGrid, PathConfig, RuleKind, SolverKind};
use crate::coordinator::run_trials;
use crate::linalg::{DenseMatrix, DesignMatrix};
use crate::util::rng::Rng;

/// Configuration for a stability-selection run.
#[derive(Clone, Debug)]
pub struct StabilityConfig {
    /// Subsample rounds (B). Meinshausen–Bühlmann suggest ≥ 100; benches
    /// use fewer.
    pub rounds: usize,
    /// λ-grid size per round (on λ/λmax ∈ [lo, 1]).
    pub grid: usize,
    pub grid_lo: f64,
    pub rule: RuleKind,
    pub solver: SolverKind,
    pub seed: u64,
}

impl Default for StabilityConfig {
    fn default() -> Self {
        StabilityConfig {
            rounds: 50,
            grid: 50,
            grid_lo: 0.1,
            rule: RuleKind::Edpp,
            solver: SolverKind::Cd,
            seed: 0x57AB,
        }
    }
}

/// Result: per-feature selection probabilities.
#[derive(Clone, Debug)]
pub struct StabilityOutput {
    /// max over λ of the selection frequency, per feature ∈ [0, 1].
    pub scores: Vec<f64>,
    /// mean rejection ratio across all rounds (screening effectiveness).
    pub mean_rejection: f64,
    /// total screen+solve seconds across rounds.
    pub total_secs: f64,
}

impl StabilityOutput {
    /// Features whose stability score passes `threshold`.
    pub fn selected(&self, threshold: f64) -> Vec<usize> {
        (0..self.scores.len()).filter(|&j| self.scores[j] >= threshold).collect()
    }
}

/// Row-subsample copy (without replacement). Matrix-free: columns are read
/// through [`DesignMatrix::col_gather`] (direct indexing on dense, binary
/// search on CSC); the per-round working set is dense — a half-row
/// subsample is small, and the round is solver-bound anyway.
fn subsample(
    x: &dyn DesignMatrix,
    y: &[f64],
    rows: &[usize],
) -> (DenseMatrix, Vec<f64>) {
    let mut xs = DenseMatrix::zeros(rows.len(), x.n_cols());
    for j in 0..x.n_cols() {
        x.col_gather(j, rows, xs.col_mut(j));
    }
    (xs, rows.iter().map(|&r| y[r]).collect())
}

/// Run stability selection with screened paths, rounds fanned out over the
/// coordinator's worker pool. `Sync` because the backend is shared across
/// the worker threads.
pub fn stability_selection(
    x: &(dyn DesignMatrix + Sync),
    y: &[f64],
    cfg: &StabilityConfig,
) -> StabilityOutput {
    let p = x.n_cols();
    let n = x.n_rows();
    let half = (n / 2).max(1);
    let path_cfg = PathConfig::default();
    let workers = crate::coordinator::default_workers();
    let per_round = run_trials(cfg.rounds, workers, |b| {
        let mut rng = Rng::new(cfg.seed ^ (b as u64).wrapping_mul(0x9E37_79B9));
        let rows = rng.sample_indices(n, half);
        let (xs, ys) = subsample(x, y, &rows);
        let grid = LambdaGrid::relative(&xs, &ys, cfg.grid, cfg.grid_lo, 1.0);
        let out = solve_path(&xs, &ys, &grid, cfg.rule, cfg.solver, &path_cfg);
        // per-feature: selected at any λ this round?
        let mut hit = vec![false; p];
        for beta in &out.betas {
            for j in 0..p {
                if beta[j] != 0.0 {
                    hit[j] = true;
                }
            }
        }
        (hit, out.mean_rejection_ratio(), out.total_secs())
    });

    let mut scores = vec![0.0; p];
    let mut rej = 0.0;
    let mut secs = 0.0;
    for (hit, r, s) in &per_round {
        for j in 0..p {
            if hit[j] {
                scores[j] += 1.0 / cfg.rounds as f64;
            }
        }
        rej += r / cfg.rounds as f64;
        secs += s;
    }
    StabilityOutput { scores, mean_rejection: rej, total_secs: secs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn recovers_strong_signals() {
        // planted support with large coefficients must dominate the scores
        let ds = synthetic::synthetic1(60, 150, 8, 0.05, 9);
        let truth = ds.beta_true.clone().unwrap();
        let cfg = StabilityConfig { rounds: 12, grid: 15, ..Default::default() };
        let out = stability_selection(&ds.x, &ds.y, &cfg);
        // every strong true feature (|β*| > 0.5) should score higher than
        // the median null feature
        let null_scores: Vec<f64> = (0..150).filter(|&j| truth[j] == 0.0).map(|j| out.scores[j]).collect();
        let null_med = crate::util::stats::median(&null_scores);
        for j in 0..150 {
            if truth[j].abs() > 0.5 {
                assert!(
                    out.scores[j] >= null_med,
                    "strong feature {j} scored {} < null median {null_med}",
                    out.scores[j]
                );
            }
        }
        assert!(out.mean_rejection > 0.5);
    }

    #[test]
    fn selected_threshold_monotone() {
        let ds = synthetic::synthetic1(40, 80, 6, 0.1, 10);
        let cfg = StabilityConfig { rounds: 6, grid: 8, ..Default::default() };
        let out = stability_selection(&ds.x, &ds.y, &cfg);
        assert!(out.selected(0.9).len() <= out.selected(0.5).len());
        assert!(out.selected(0.0).len() == 80);
        assert!(out.scores.iter().all(|s| (0.0..=1.0 + 1e-12).contains(s)));
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = synthetic::synthetic1(30, 60, 5, 0.1, 11);
        let cfg = StabilityConfig { rounds: 4, grid: 6, ..Default::default() };
        let a = stability_selection(&ds.x, &ds.y, &cfg);
        let b = stability_selection(&ds.x, &ds.y, &cfg);
        assert_eq!(a.scores, b.scores);
    }
}
