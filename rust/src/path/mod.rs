//! Pathwise λ-grid driver: the paper's experimental protocol (solve the
//! Lasso along 100 values of λ/λmax ∈ [0.05, 1.0], screening sequentially
//! with the exact solution at the previous λ, warm-starting the solver, and
//! recording the two headline metrics — *rejection ratio* and *speedup*).
//!
//! Screening runs through the stateful [`Screener`] lifecycle
//! (DESIGN.md §3): the driver `init`s the pipeline once, calls
//! `screen_step` per λ and `observe`s each exact solution — the pipeline
//! owns θ-propagation. Composed pipelines (`cascade:…`, `hybrid:…`,
//! `dynamic:…`) report per-stage discard counts in each [`StepRecord`];
//! single-rule pipelines are bit-identical to the pre-lifecycle driver.

pub mod group;
pub mod stability;

use std::time::{Duration, Instant};

use crate::linalg::DesignMatrix;
use crate::screening::{
    pipeline::merge_kkt_candidates, strong::kkt_violations, strong::kkt_violations_in,
    GapSafeHook, ScreenContext, ScreenPipeline, Screener, StageCount,
};
use crate::solver::{
    cd::CdSolver,
    fista::FistaSolver,
    lars::LarsSolver,
    working_set::{solve_working_set, WorkingSetState},
    LassoSolver, SolveOptions,
};
use crate::util::timer::timed;

/// Descending λ grid, the paper's protocol: equally spaced on the λ/λmax
/// scale.
#[derive(Clone, Debug)]
pub struct LambdaGrid {
    pub lam_max: f64,
    /// Descending λ values (λmax-relative grid 1.0 → lo).
    pub values: Vec<f64>,
}

impl LambdaGrid {
    /// `k` values equally spaced on λ/λmax ∈ [lo, hi], descending.
    /// The paper uses k = 100, lo = 0.05, hi = 1.0.
    pub fn relative(
        x: &dyn DesignMatrix,
        y: &[f64],
        k: usize,
        lo: f64,
        hi: f64,
    ) -> LambdaGrid {
        let lam_max = crate::solver::dual::lambda_max(x, y);
        Self::relative_to(lam_max, k, lo, hi)
    }

    /// Same but from a precomputed λmax (group-Lasso paths etc.).
    pub fn relative_to(lam_max: f64, k: usize, lo: f64, hi: f64) -> LambdaGrid {
        assert!(k >= 1 && lo > 0.0 && hi >= lo);
        let mut values = Vec::with_capacity(k);
        for i in 0..k {
            let t = if k == 1 { hi } else { hi - (hi - lo) * i as f64 / (k - 1) as f64 };
            values.push(t * lam_max);
        }
        LambdaGrid { lam_max, values }
    }
}

/// Which base screening rule a path run uses. Composed pipelines
/// (`cascade:…`, `hybrid:…`, `dynamic:…`) are expressed as a
/// [`ScreenPipeline`]; every `RuleKind` converts into a single-rule
/// pipeline via `Into<ScreenPipeline>`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleKind {
    /// No screening — the baseline solver timing.
    None,
    Safe,
    Dome,
    Dpp,
    Improvement1,
    Improvement2,
    Edpp,
    Strong,
    Sis,
}

impl RuleKind {
    pub const ALL_LASSO: [RuleKind; 8] = [
        RuleKind::Safe,
        RuleKind::Dome,
        RuleKind::Dpp,
        RuleKind::Improvement1,
        RuleKind::Improvement2,
        RuleKind::Edpp,
        RuleKind::Strong,
        RuleKind::Sis,
    ];

    /// Every variant including `None` — the `from_name` lookup table.
    pub const ALL_WITH_NONE: [RuleKind; 9] = [
        RuleKind::Safe,
        RuleKind::Dome,
        RuleKind::Dpp,
        RuleKind::Improvement1,
        RuleKind::Improvement2,
        RuleKind::Edpp,
        RuleKind::Strong,
        RuleKind::Sis,
        RuleKind::None,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::None => "none",
            RuleKind::Safe => "safe",
            RuleKind::Dome => "dome",
            RuleKind::Dpp => "dpp",
            RuleKind::Improvement1 => "improvement1",
            RuleKind::Improvement2 => "improvement2",
            RuleKind::Edpp => "edpp",
            RuleKind::Strong => "strong",
            RuleKind::Sis => "sis",
        }
    }

    /// Name lookup over the const table — no per-call allocation. Plain
    /// rule names only; for the full pipeline grammar (cascade/hybrid/
    /// dynamic) parse a [`ScreenPipeline`] instead.
    pub fn from_name(s: &str) -> Option<RuleKind> {
        Self::ALL_WITH_NONE.iter().copied().find(|r| r.name() == s)
    }
}

impl From<RuleKind> for ScreenPipeline {
    fn from(rule: RuleKind) -> ScreenPipeline {
        ScreenPipeline::single(rule.name())
    }
}

/// Which solver substrate the path uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    Cd,
    Fista,
    Lars,
}

impl SolverKind {
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Cd => "cd",
            SolverKind::Fista => "fista",
            SolverKind::Lars => "lars",
        }
    }

    pub fn from_name(s: &str) -> Option<SolverKind> {
        [SolverKind::Cd, SolverKind::Fista, SolverKind::Lars]
            .into_iter()
            .find(|k| k.name() == s)
    }

    /// Instantiate the solver (unit structs — free). Shared with the
    /// serving coordinator, which re-instantiates per batch.
    pub(crate) fn make(&self) -> Box<dyn LassoSolver> {
        match self {
            SolverKind::Cd => Box::new(CdSolver),
            SolverKind::Fista => Box::new(FistaSolver),
            SolverKind::Lars => Box::new(LarsSolver),
        }
    }
}

/// How the path driver solves each λ step.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PathStrategy {
    /// Screen-first (the paper's protocol): shrink from p with the
    /// pipeline, solve the survivors, KKT-repair heuristic discards.
    #[default]
    Screen,
    /// Working-set: *grow* a restricted problem from the pipeline
    /// survivors and certify against the **full-problem** duality gap
    /// ([`crate::solver::working_set`], DESIGN.md §3b). Tolerance-exact —
    /// same gap contract, not bit-identical to screen-first.
    WorkingSet,
}

impl PathStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            PathStrategy::Screen => "screen",
            PathStrategy::WorkingSet => "working-set",
        }
    }

    pub fn from_name(s: &str) -> Option<PathStrategy> {
        match s {
            "screen" => Some(PathStrategy::Screen),
            "working-set" | "ws" => Some(PathStrategy::WorkingSet),
            _ => None,
        }
    }
}

/// Path-run configuration.
#[derive(Clone, Debug)]
pub struct PathConfig {
    /// Sequential rules (paper default). When false, every step anchors at
    /// λ₀ = λmax with θ = y/λmax — the "basic" versions of §4.1.1.
    pub sequential: bool,
    /// Run the KKT violation/repair loop after heuristic-rule solves.
    pub kkt_repair: bool,
    /// Warm-start each solve from the previous λ's solution.
    pub warm_start: bool,
    /// Relative slack widening keep-decisions when the matrix values are
    /// reduced-precision (f32 shards, the PJRT sweep): keep *more*
    /// features, never discard an active one (DESIGN.md §1). 0.0 for the
    /// exact f64 backends.
    pub safety_slack: f64,
    /// Wall-clock budget for the *whole* path. When set, the driver
    /// re-splits the remaining budget across the remaining λ-grid before
    /// every solve ([`replan_step_budget`]), so steps that finish early
    /// donate their slack downstream instead of stranding it. `None` (the
    /// default) leaves `solve_opts.time_budget` untouched — bit-identical
    /// to the un-budgeted driver.
    pub path_budget: Option<Duration>,
    /// Per-λ solve strategy: screen-first (default, bit-identical to the
    /// historical driver) or the working-set engine.
    pub strategy: PathStrategy,
    pub solve_opts: SolveOptions,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            sequential: true,
            kkt_repair: true,
            warm_start: true,
            safety_slack: 0.0,
            path_budget: None,
            strategy: PathStrategy::Screen,
            solve_opts: SolveOptions::default(),
        }
    }
}

/// The deadline re-plan: an even split of what's *left* over the steps
/// still to run. Called before every step, this dominates the one-shot
/// `total / steps` split: a step that uses less than its slice returns the
/// difference to the pool, and a λ ≥ λmax trivial step (cost ≈ 0) donates
/// its entire slice at the next re-plan. `steps_left == 0` is answered
/// with the full remainder (defensive; the driver never asks).
pub fn replan_step_budget(remaining: Duration, steps_left: usize) -> Duration {
    remaining / steps_left.clamp(1, u32::MAX as usize) as u32
}

/// Per-λ record.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub lam: f64,
    /// Features surviving screening (before KKT repair additions).
    pub kept: usize,
    /// Features discarded by the final mask (after repairs; includes
    /// in-solver dynamic discards).
    pub discarded: usize,
    /// Exactly-zero coefficients in the solution at this λ.
    pub true_zeros: usize,
    pub screen_secs: f64,
    pub solve_secs: f64,
    pub solver_iters: usize,
    /// KKT repair rounds triggered (heuristic rules only).
    pub kkt_repairs: usize,
    pub gap: f64,
    /// Per-pipeline-stage discard counts in stage order (empty for the
    /// trivial λ ≥ λmax steps).
    pub stage_discards: Vec<StageCount>,
    /// Features additionally discarded *inside* the solver by the gap-safe
    /// hook (`dynamic:` pipelines only).
    pub dynamic_discards: usize,
    /// Size of the reduced problem actually solved at this λ — the final
    /// working set under [`PathStrategy::WorkingSet`], the post-repair
    /// survivor count under screen-first. How much of p this λ touched.
    pub working_set_size: usize,
    /// Complement/full KKT sweeps paid at this λ (certification +
    /// expansion rounds under working-set, repair checks under
    /// screen-first; 0 for safe screen-first steps, which need none).
    pub kkt_passes: usize,
}

impl StepRecord {
    /// The paper's rejection ratio: discarded / true zeros (≤ 1 for safe
    /// rules; repaired heuristics also end ≤ 1). Steps with no true zeros
    /// (p = 0 degenerate problems, dense-support steps) have nothing to
    /// reject and return 0.0 — never NaN.
    pub fn rejection_ratio(&self) -> f64 {
        if self.true_zeros == 0 {
            0.0
        } else {
            self.discarded as f64 / self.true_zeros as f64
        }
    }
}

/// Output of a full path run.
#[derive(Clone, Debug)]
pub struct PathOutput {
    /// Canonical pipeline name (`"edpp"`, `"hybrid:strong+edpp"`, …).
    pub rule: String,
    pub solver: &'static str,
    pub records: Vec<StepRecord>,
    /// Full-length solutions per λ (same order as `records`).
    pub betas: Vec<Vec<f64>>,
}

impl PathOutput {
    pub fn mean_rejection_ratio(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        // audit:allow(determinism:float-sum, per-step summary ratio off the solve path)
        self.records.iter().map(|r| r.rejection_ratio()).sum::<f64>()
            / self.records.len() as f64
    }

    /// Mean per-stage rejection contribution: for each pipeline stage (in
    /// pipeline order), the average over λ-steps of that stage's discards
    /// divided by the step's true zeros (0 when there are none).
    pub fn mean_stage_rejections(&self) -> Vec<(String, f64)> {
        if self.records.is_empty() {
            return Vec::new();
        }
        let mut out: Vec<(String, f64)> = Vec::new();
        for r in &self.records {
            for sc in &r.stage_discards {
                let ratio = if r.true_zeros == 0 {
                    0.0
                } else {
                    sc.discarded as f64 / r.true_zeros as f64
                };
                match out.iter_mut().find(|(n, _)| n == &sc.stage) {
                    Some((_, s)) => *s += ratio,
                    None => out.push((sc.stage.clone(), ratio)),
                }
            }
        }
        for (_, s) in out.iter_mut() {
            *s /= self.records.len() as f64;
        }
        out
    }

    /// Total features dropped in-solver by the gap-safe hook.
    pub fn total_dynamic_discards(&self) -> usize {
        self.records.iter().map(|r| r.dynamic_discards).sum()
    }

    pub fn total_screen_secs(&self) -> f64 {
        self.records.iter().map(|r| r.screen_secs).sum()
    }

    pub fn total_solve_secs(&self) -> f64 {
        self.records.iter().map(|r| r.solve_secs).sum()
    }

    pub fn total_secs(&self) -> f64 {
        self.total_screen_secs() + self.total_solve_secs()
    }

    pub fn total_kkt_repairs(&self) -> usize {
        self.records.iter().map(|r| r.kkt_repairs).sum()
    }

    /// Mean reduced-problem size across steps — the "how much of p did
    /// each λ pay" number the bench and `PathSummary` surface.
    pub fn mean_working_set(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.working_set_size).sum::<usize>() as f64
            / self.records.len() as f64
    }

    pub fn total_kkt_passes(&self) -> usize {
        self.records.iter().map(|r| r.kkt_passes).sum()
    }
}

/// Solve the Lasso along `grid` with screening `rule` and solver `solver`.
///
/// This is the library's primary entry point (the coordinator and all
/// benches build on it). `x` is any [`DesignMatrix`] backend — dense or
/// CSC — and the whole EDPP protocol runs matrix-free on it.
pub fn solve_path(
    x: &dyn DesignMatrix,
    y: &[f64],
    grid: &LambdaGrid,
    rule: RuleKind,
    solver: SolverKind,
    cfg: &PathConfig,
) -> PathOutput {
    solve_path_pipeline(x, y, grid, &rule.into(), solver, cfg)
}

/// Like [`solve_path`] but with a composed screening pipeline — the
/// `--rule cascade:…|hybrid:…|dynamic:…` entry point.
pub fn solve_path_pipeline(
    x: &dyn DesignMatrix,
    y: &[f64],
    grid: &LambdaGrid,
    pipeline: &ScreenPipeline,
    solver: SolverKind,
    cfg: &PathConfig,
) -> PathOutput {
    // with_sweep_slack(x, y, x, 0.0) is exactly ScreenContext::new
    let ctx = ScreenContext::with_sweep_slack(x, y, x, cfg.safety_slack);
    let mut screener = pipeline.build(x.n_rows(), cfg.sequential);
    solve_path_with_screener(&ctx, grid, screener.as_mut(), solver, cfg)
}

/// Like [`solve_path`] but with a caller-provided context (so the PJRT
/// runtime sweep can be injected via [`ScreenContext::with_sweep`]).
pub fn solve_path_with_ctx(
    ctx: &ScreenContext,
    grid: &LambdaGrid,
    rule_kind: RuleKind,
    solver_kind: SolverKind,
    cfg: &PathConfig,
) -> PathOutput {
    let mut screener =
        ScreenPipeline::from(rule_kind).build(ctx.x.n_rows(), cfg.sequential);
    solve_path_with_screener(ctx, grid, screener.as_mut(), solver_kind, cfg)
}

/// The lifecycle driver every other entry point funnels into: `init` the
/// pipeline, `screen_step` each λ, solve (with the gap-safe hook when the
/// pipeline asks for it), KKT-repair the *uncertified* discards, and
/// `observe` the exact solution back into the pipeline. Under
/// [`PathStrategy::WorkingSet`] the per-λ solve instead grows a working set
/// from the survivors and certifies the full-problem gap (DESIGN.md §3b);
/// this entry point runs it with a fresh (path-local) warm-start state —
/// long-lived callers thread their own via
/// [`solve_path_with_screener_warm`].
pub fn solve_path_with_screener(
    ctx: &ScreenContext,
    grid: &LambdaGrid,
    screener: &mut dyn Screener,
    solver_kind: SolverKind,
    cfg: &PathConfig,
) -> PathOutput {
    let mut ws_state = WorkingSetState::default();
    solve_path_with_screener_warm(ctx, grid, screener, solver_kind, cfg, &mut ws_state)
}

/// [`solve_path_with_screener`] with a caller-owned working-set warm-start
/// state: the accumulated working set, β and solver momentum persist across
/// calls, so a serving session's repeat `FitPath` seeds every λ from the
/// union of all active sets it has ever solved — its complement sweeps find
/// no violators and certify in one pass (O(active set) per λ, not O(p)).
/// Ignored (never read or written) under [`PathStrategy::Screen`].
pub fn solve_path_with_screener_warm(
    ctx: &ScreenContext,
    grid: &LambdaGrid,
    screener: &mut dyn Screener,
    solver_kind: SolverKind,
    cfg: &PathConfig,
    ws_state: &mut WorkingSetState,
) -> PathOutput {
    let x = ctx.x;
    let y = ctx.y;
    let p = x.n_cols();
    let solver = solver_kind.make();

    let mut records = Vec::with_capacity(grid.values.len());
    let mut betas = Vec::with_capacity(grid.values.len());

    // the pipeline owns θ-propagation; the driver only keeps the previous
    // solution for warm starts
    screener.init(ctx);
    let mut beta_prev: Vec<f64> = vec![0.0; p];

    // scratch hoisted out of the λ loop (§Perf): the keep mask and the
    // KKT-repair residual are reused at every step instead of reallocated
    let mut keep = vec![true; p];
    let mut resid = vec![0.0; y.len()];

    // deadline re-planning state: under a path budget each step's
    // time_budget is re-derived from what actually remains, so early
    // finishers donate slack downstream. KKT-repair re-solves within a
    // step reuse that step's slice (a deliberate simplification: repairs
    // are rare and cheap next to the main solve).
    // audit:allow(determinism:clock, path-level deadline anchor; gates work, not values)
    let path_t0 = Instant::now();
    let total_steps = grid.values.len();
    let mut solve_opts = cfg.solve_opts.clone();

    for (step_idx, &lam) in grid.values.iter().enumerate() {
        if let Some(budget) = cfg.path_budget {
            solve_opts.time_budget = Some(replan_step_budget(
                budget.saturating_sub(path_t0.elapsed()),
                total_steps - step_idx,
            ));
        }
        if lam >= ctx.lam_max * (1.0 - 1e-12) {
            // trivial solution (eq. (8)); everything is screened by eq. (9).
            // The working-set warm state is *kept*: β = 0 here says nothing
            // about the active sets accumulated at smaller λ.
            records.push(StepRecord {
                lam,
                kept: 0,
                discarded: p,
                true_zeros: p,
                screen_secs: 0.0,
                solve_secs: 0.0,
                solver_iters: 0,
                kkt_repairs: 0,
                gap: 0.0,
                stage_discards: Vec::new(),
                dynamic_discards: 0,
                working_set_size: 0,
                kkt_passes: 0,
            });
            betas.push(vec![0.0; p]);
            screener.init(ctx); // reset every stage to the λmax anchor
            beta_prev.fill(0.0);
            continue;
        }

        // ---- screening (staged pipeline) ----
        keep.fill(true);
        let (stage_discards, screen_secs) =
            timed(|| screener.screen_step(ctx, lam, &mut keep));
        let kept0 = keep.iter().filter(|k| **k).count();

        if cfg.strategy == PathStrategy::WorkingSet {
            // ---- working-set solve: grow from the survivors, certify the
            // full-problem gap (the screen mask is only a seed here) ----
            let (wres, solve_secs) = timed(|| {
                solve_working_set(ctx, lam, &keep, solver.as_ref(), &solve_opts, ws_state)
            });
            let true_zeros = wres.beta.iter().filter(|b| **b == 0.0).count();
            records.push(StepRecord {
                lam,
                kept: kept0,
                discarded: p - wres.working_set_size,
                true_zeros,
                screen_secs,
                solve_secs,
                solver_iters: wres.iters,
                kkt_repairs: wres.expansions,
                gap: wres.gap,
                stage_discards,
                dynamic_discards: 0,
                working_set_size: wres.working_set_size,
                kkt_passes: wres.kkt_passes,
            });
            screener.observe(ctx, lam, &wres.beta);
            beta_prev.copy_from_slice(&wres.beta);
            betas.push(wres.beta);
            continue;
        }

        // ---- reduced solve (+ KKT repair on the uncertified discards) ----
        let is_safe = screener.is_safe();
        let mut kkt_repairs = 0usize;
        let mut kkt_passes = 0usize;
        let mut dynamic_discards = 0usize;
        let mut hook =
            if screener.dynamic() { Some(GapSafeHook::new(ctx)) } else { None };
        // under a heuristic pipeline the hook's certificates are issued
        // against a possibly-unrepaired reduced problem, so its drops must
        // join the KKT-repair candidate set and be re-validated
        let mut hook_dropped: Vec<bool> =
            if hook.is_some() && !is_safe { vec![false; p] } else { Vec::new() };
        let mut cols: Vec<usize> = (0..p).filter(|&j| keep[j]).collect();
        let mut result: Option<crate::solver::SolveResult> = None;
        let (res, solve_secs) = timed(|| {
            loop {
                let warm: Option<Vec<f64>> = if cfg.warm_start {
                    Some(cols.iter().map(|&j| beta_prev[j]).collect())
                } else {
                    None
                };
                let r = match hook.as_mut() {
                    Some(h) => solver.solve_with_hook(
                        x,
                        y,
                        &cols,
                        lam,
                        warm.as_deref(),
                        &solve_opts,
                        Some(h),
                    ),
                    None => solver.solve(x, y, &cols, lam, warm.as_deref(), &solve_opts),
                };
                // fold in-solver gap-safe drops into the step's final mask
                if let Some(h) = hook.as_mut() {
                    let revalidate = if is_safe { None } else { Some(&mut hook_dropped) };
                    dynamic_discards += h.fold_into(&mut keep, revalidate);
                }
                result = Some(r);
                if is_safe || !cfg.kkt_repair {
                    break;
                }
                // heuristic: check KKT on the full problem — but only the
                // *uncertified* discards when the pipeline certifies some
                let res = result.as_ref().unwrap();
                resid.copy_from_slice(y);
                for (k, &j) in cols.iter().enumerate() {
                    if res.beta[k] != 0.0 {
                        x.col_axpy_into(j, -res.beta[k], &mut resid);
                    }
                }
                kkt_passes += 1;
                let viol = match screener.uncertified() {
                    Some(cand) if !hook_dropped.is_empty() => {
                        // hook drops are not in the certifier's candidate
                        // mask — merge them in so they get re-validated
                        let merged = merge_kkt_candidates(cand, &hook_dropped);
                        kkt_violations_in(ctx, &resid, lam, &keep, &merged)
                    }
                    Some(cand) => kkt_violations_in(ctx, &resid, lam, &keep, cand),
                    None => kkt_violations(ctx, &resid, lam, &keep),
                };
                if viol.is_empty() {
                    break;
                }
                kkt_repairs += 1;
                for j in viol {
                    keep[j] = true;
                }
                cols = (0..p).filter(|&j| keep[j]).collect();
            }
            result.take().unwrap()
        });

        let full = res.scatter(&cols, p);
        let true_zeros = full.iter().filter(|b| **b == 0.0).count();
        let discarded = keep.iter().filter(|k| !**k).count();

        records.push(StepRecord {
            lam,
            kept: kept0,
            discarded,
            true_zeros,
            screen_secs,
            solve_secs,
            solver_iters: res.iters,
            kkt_repairs,
            gap: res.gap,
            stage_discards,
            dynamic_discards,
            working_set_size: cols.len(),
            kkt_passes,
        });

        // advance the pipeline's sequential state with the exact solution
        screener.observe(ctx, lam, &full);
        beta_prev.copy_from_slice(&full);
        betas.push(full);
    }

    PathOutput {
        rule: screener.name(),
        solver: solver_kind.name(),
        records,
        betas,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn grid_for(ds: &crate::data::Dataset, k: usize) -> LambdaGrid {
        LambdaGrid::relative(&ds.x, &ds.y, k, 0.05, 1.0)
    }

    #[test]
    fn replan_first_slice_is_the_even_split() {
        // before anything runs, the re-plan is exactly the old one-shot
        // even split — the change only shows once slack appears
        assert_eq!(
            replan_step_budget(Duration::from_secs(10), 5),
            Duration::from_secs(2)
        );
        // zero steps left never divides by zero (full remainder back)
        assert_eq!(
            replan_step_budget(Duration::from_secs(1), 0),
            Duration::from_secs(1)
        );
        assert_eq!(replan_step_budget(Duration::ZERO, 3), Duration::ZERO);
    }

    #[test]
    fn replan_donates_early_finisher_slack_downstream() {
        // 1000 ms over 4 steps; the first two steps finish in a quarter of
        // their slice. Under the re-plan, later steps inherit the slack;
        // the one-shot even split would have pinned every slice at 250 ms.
        let total = Duration::from_millis(1000);
        let mut elapsed = Duration::ZERO;
        let mut slices = Vec::new();
        for step in 0..4usize {
            let slice = replan_step_budget(total.saturating_sub(elapsed), 4 - step);
            slices.push(slice);
            elapsed += if step < 2 { slice / 4 } else { slice };
        }
        assert_eq!(slices[0], Duration::from_millis(250));
        // 937.5 ms left over 3 steps
        assert_eq!(slices[1], Duration::from_nanos(312_500_000));
        // 859.375 ms left over 2 steps — well above the even split's 250 ms
        assert_eq!(slices[2], Duration::from_nanos(429_687_500));
        assert!(slices[2] > slices[0]);
        assert_eq!(slices[3], slices[2]); // last step gets all that remains
    }

    #[test]
    fn generous_path_budget_is_bit_identical_to_none() {
        // path_budget only re-derives time_budget; with a budget no solve
        // comes close to exhausting, trajectories must match exactly
        let ds = synthetic::synthetic1(24, 60, 6, 0.1, 11);
        let grid = grid_for(&ds, 6);
        let base = solve_path(
            &ds.x,
            &ds.y,
            &grid,
            RuleKind::Edpp,
            SolverKind::Cd,
            &PathConfig::default(),
        );
        let budgeted_cfg = PathConfig {
            path_budget: Some(Duration::from_secs(600)),
            ..Default::default()
        };
        let budgeted = solve_path(
            &ds.x,
            &ds.y,
            &grid,
            RuleKind::Edpp,
            SolverKind::Cd,
            &budgeted_cfg,
        );
        assert_eq!(base.betas, budgeted.betas);
    }

    #[test]
    fn grid_is_descending_and_spans() {
        let ds = synthetic::synthetic1(20, 40, 4, 0.1, 1);
        let g = grid_for(&ds, 10);
        assert_eq!(g.values.len(), 10);
        assert!((g.values[0] - g.lam_max).abs() < 1e-12);
        assert!((g.values[9] - 0.05 * g.lam_max).abs() < 1e-12);
        for w in g.values.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn edpp_path_safe_and_exact() {
        // the screened path must reproduce the unscreened solutions exactly
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, 2);
        let g = grid_for(&ds, 12);
        let cfg = PathConfig::default();
        let screened = solve_path(&ds.x, &ds.y, &g, RuleKind::Edpp, SolverKind::Cd, &cfg);
        let baseline = solve_path(&ds.x, &ds.y, &g, RuleKind::None, SolverKind::Cd, &cfg);
        for (k, (bs, bb)) in screened.betas.iter().zip(baseline.betas.iter()).enumerate() {
            for j in 0..ds.p() {
                assert!(
                    (bs[j] - bb[j]).abs() < 1e-4 * (1.0 + bb[j].abs()),
                    "λ-index {k}, feature {j}: {} vs {}",
                    bs[j],
                    bb[j]
                );
            }
        }
        assert!(screened.mean_rejection_ratio() <= 1.0 + 1e-12);
        assert!(screened.mean_rejection_ratio() > 0.8);
    }

    #[test]
    fn strong_path_with_repair_is_exact() {
        let ds = synthetic::synthetic2(25, 100, 10, 0.1, 3);
        let g = grid_for(&ds, 10);
        let cfg = PathConfig::default();
        let strong = solve_path(&ds.x, &ds.y, &g, RuleKind::Strong, SolverKind::Cd, &cfg);
        let baseline = solve_path(&ds.x, &ds.y, &g, RuleKind::None, SolverKind::Cd, &cfg);
        for (bs, bb) in strong.betas.iter().zip(baseline.betas.iter()) {
            for j in 0..ds.p() {
                assert!((bs[j] - bb[j]).abs() < 1e-4 * (1.0 + bb[j].abs()));
            }
        }
    }

    /// Working-set strategy end to end: same solutions as the screen-first
    /// driver to gap tolerance, every step certified, counters populated.
    #[test]
    fn working_set_path_matches_screen_first() {
        let ds = synthetic::synthetic1(25, 200, 10, 0.1, 12);
        let g = grid_for(&ds, 10);
        let base = solve_path(
            &ds.x,
            &ds.y,
            &g,
            RuleKind::None,
            SolverKind::Cd,
            &PathConfig::default(),
        );
        let ws_cfg = PathConfig { strategy: PathStrategy::WorkingSet, ..Default::default() };
        let ws = solve_path(&ds.x, &ds.y, &g, RuleKind::Strong, SolverKind::Cd, &ws_cfg);
        assert_eq!(ws.betas.len(), base.betas.len());
        for (k, (bs, bb)) in ws.betas.iter().zip(base.betas.iter()).enumerate() {
            for j in 0..ds.p() {
                assert!(
                    (bs[j] - bb[j]).abs() < 2e-4 * (1.0 + bb[j].abs()),
                    "λ-index {k}, feature {j}: {} vs {}",
                    bs[j],
                    bb[j]
                );
            }
        }
        // every non-trivial step is full-problem certified and reports the
        // reduced size it actually paid
        let tol = PathConfig::default().solve_opts.tol_gap;
        for r in ws.records.iter().skip(1) {
            assert!(r.gap <= tol, "uncertified step at λ={}: gap {}", r.lam, r.gap);
            assert!(r.kkt_passes >= 1, "no certification sweep at λ={}", r.lam);
            assert!(r.working_set_size + r.discarded == ds.p());
        }
        let last = ws.records.last().unwrap();
        assert!(last.working_set_size >= 1);
        assert!(ws.mean_working_set() < ds.p() as f64);
        assert!(ws.total_kkt_passes() >= ws.records.len() - 1);
    }

    #[test]
    fn basic_mode_weaker_than_sequential() {
        // §4.1: sequential rules dominate their basic versions
        let ds = synthetic::synthetic1(30, 150, 12, 0.1, 4);
        let g = grid_for(&ds, 15);
        let seq_cfg = PathConfig::default();
        let basic_cfg = PathConfig { sequential: false, ..Default::default() };
        let seq = solve_path(&ds.x, &ds.y, &g, RuleKind::Edpp, SolverKind::Cd, &seq_cfg);
        let basic = solve_path(&ds.x, &ds.y, &g, RuleKind::Edpp, SolverKind::Cd, &basic_cfg);
        assert!(
            seq.mean_rejection_ratio() >= basic.mean_rejection_ratio() - 1e-9,
            "seq {} < basic {}",
            seq.mean_rejection_ratio(),
            basic.mean_rejection_ratio()
        );
    }

    #[test]
    fn rejection_ratios_bounded_for_safe_rules() {
        let ds = synthetic::synthetic1(25, 80, 8, 0.1, 5);
        let g = grid_for(&ds, 8);
        for rule in [RuleKind::Safe, RuleKind::Dpp, RuleKind::Edpp] {
            let out = solve_path(&ds.x, &ds.y, &g, rule, SolverKind::Cd, &PathConfig::default());
            for r in &out.records {
                assert!(
                    r.rejection_ratio() <= 1.0 + 1e-12,
                    "{}: ratio {}",
                    rule.name(),
                    r.rejection_ratio()
                );
            }
        }
    }

    #[test]
    fn lars_path_matches_cd_path() {
        let ds = synthetic::synthetic1(20, 60, 6, 0.1, 6);
        let g = grid_for(&ds, 6);
        let cfg = PathConfig::default();
        let lars = solve_path(&ds.x, &ds.y, &g, RuleKind::Edpp, SolverKind::Lars, &cfg);
        let cd = solve_path(&ds.x, &ds.y, &g, RuleKind::Edpp, SolverKind::Cd, &cfg);
        for (bl, bc) in lars.betas.iter().zip(cd.betas.iter()) {
            for j in 0..ds.p() {
                assert!((bl[j] - bc[j]).abs() < 1e-3 * (1.0 + bc[j].abs()));
            }
        }
    }

    #[test]
    fn rule_and_solver_name_roundtrip() {
        for r in RuleKind::ALL_LASSO {
            assert_eq!(RuleKind::from_name(r.name()), Some(r));
        }
        assert_eq!(RuleKind::from_name("none"), Some(RuleKind::None));
        assert_eq!(RuleKind::from_name("cascade:sis,edpp"), None);
        for s in [SolverKind::Cd, SolverKind::Fista, SolverKind::Lars] {
            assert_eq!(SolverKind::from_name(s.name()), Some(s));
        }
    }

    /// Satellite: rejection_ratio must never be NaN — p = 0 problems and
    /// dense-support steps (no true zeros) report 0.0, and an empty path
    /// reports a 0.0 mean.
    #[test]
    fn rejection_ratio_degenerate_cases() {
        let zero = StepRecord {
            lam: 1.0,
            kept: 0,
            discarded: 0,
            true_zeros: 0,
            screen_secs: 0.0,
            solve_secs: 0.0,
            solver_iters: 0,
            kkt_repairs: 0,
            gap: 0.0,
            stage_discards: Vec::new(),
            dynamic_discards: 0,
            working_set_size: 0,
            kkt_passes: 0,
        };
        assert_eq!(zero.rejection_ratio(), 0.0);
        assert!(!zero.rejection_ratio().is_nan());
        let dense_support = StepRecord { discarded: 3, ..zero.clone() };
        assert_eq!(dense_support.rejection_ratio(), 0.0);
        let empty = PathOutput {
            rule: "edpp".to_string(),
            solver: "cd",
            records: Vec::new(),
            betas: Vec::new(),
        };
        assert_eq!(empty.mean_rejection_ratio(), 0.0);
        assert!(!empty.mean_rejection_ratio().is_nan());
        assert!(empty.mean_stage_rejections().is_empty());
    }

    /// Hybrid pipeline along a full path: exact solutions, rejection at
    /// least the certifier's, and per-stage counts that add up.
    #[test]
    fn hybrid_path_exact_and_dominates_certifier() {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, 7);
        let g = grid_for(&ds, 10);
        let cfg = PathConfig::default();
        let pipe = ScreenPipeline::parse("hybrid:strong+edpp").unwrap();
        let hyb = solve_path_pipeline(&ds.x, &ds.y, &g, &pipe, SolverKind::Cd, &cfg);
        let edpp = solve_path(&ds.x, &ds.y, &g, RuleKind::Edpp, SolverKind::Cd, &cfg);
        let base = solve_path(&ds.x, &ds.y, &g, RuleKind::None, SolverKind::Cd, &cfg);
        assert_eq!(hyb.rule, "hybrid:strong+edpp");
        for (bs, bb) in hyb.betas.iter().zip(base.betas.iter()) {
            for j in 0..ds.p() {
                assert!((bs[j] - bb[j]).abs() < 2e-4 * (1.0 + bb[j].abs()));
            }
        }
        // the hybrid mask is a subset of the certifier's keep-set, so its
        // rejection ratio dominates plain EDPP at every step
        for (h, e) in hyb.records.iter().zip(edpp.records.iter()) {
            assert!(
                h.discarded >= e.discarded,
                "hybrid discarded {} < edpp {} at λ={}",
                h.discarded,
                e.discarded,
                h.lam
            );
        }
        assert!(hyb.mean_rejection_ratio() >= edpp.mean_rejection_ratio() - 1e-12);
        // per-stage counts are recorded and consistent
        let staged = hyb
            .records
            .iter()
            .find(|r| !r.stage_discards.is_empty())
            .expect("non-trivial steps have stage records");
        assert_eq!(staged.stage_discards.len(), 2);
        assert_eq!(staged.stage_discards[0].stage, "edpp");
        assert_eq!(staged.stage_discards[1].stage, "strong");
    }

    /// Dynamic (gap-safe) pipeline: exact solutions and a final mask at
    /// least as aggressive as the static rule's.
    #[test]
    fn dynamic_path_exact_and_counts_dynamic_discards() {
        let ds = synthetic::synthetic1(30, 120, 10, 0.1, 8);
        let g = grid_for(&ds, 10);
        let cfg = PathConfig::default();
        let pipe = ScreenPipeline::parse("dynamic:edpp").unwrap();
        let dynp = solve_path_pipeline(&ds.x, &ds.y, &g, &pipe, SolverKind::Cd, &cfg);
        let edpp = solve_path(&ds.x, &ds.y, &g, RuleKind::Edpp, SolverKind::Cd, &cfg);
        let base = solve_path(&ds.x, &ds.y, &g, RuleKind::None, SolverKind::Cd, &cfg);
        assert_eq!(dynp.rule, "dynamic:edpp");
        for (bs, bb) in dynp.betas.iter().zip(base.betas.iter()) {
            for j in 0..ds.p() {
                assert!((bs[j] - bb[j]).abs() < 2e-4 * (1.0 + bb[j].abs()));
            }
        }
        for (d, e) in dynp.records.iter().zip(edpp.records.iter()) {
            assert!(d.discarded >= e.discarded, "dynamic lost discards at λ={}", d.lam);
            assert!(d.rejection_ratio() <= 1.0 + 1e-12, "unsafe dynamic discard");
        }
        assert!(dynp.mean_rejection_ratio() >= edpp.mean_rejection_ratio() - 1e-12);
        // internal consistency: a safe dynamic pipeline's final mask is
        // exactly (screen-stage discards + in-solver dynamic discards)
        for r in dynp.records.iter().filter(|r| !r.stage_discards.is_empty()) {
            let staged: usize = r.stage_discards.iter().map(|s| s.discarded).sum();
            assert_eq!(staged + r.dynamic_discards, r.discarded, "λ={}", r.lam);
        }
    }
}
