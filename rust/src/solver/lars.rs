//! LARS with the Lasso modification (Efron et al. [15]) as a λ-homotopy:
//! the solution path is piecewise linear in λ — on a segment with active set
//! A and signs s, `β_A(λ) = u − λ·v` with `u = G⁻¹X_Aᵀy`, `v = G⁻¹s`,
//! `G = X_AᵀX_A`. We walk knots (feature joins / sign-zero drops) downward
//! from λmax until the target λ, exactly as the paper's §4.1.2 "EDPP with
//! LARS" experiments require (LARS restarts per λ; screening shrinks p).
//!
//! The Cholesky factor of G is rank-1 *updated* on joins (O(k²)) and
//! recomputed on the (rare) drops.

use super::{dual, LassoSolver, SolveOptions, SolveResult};
use crate::linalg::{dot, DesignMatrix};

/// Lower-triangular Cholesky factor with append-column update.
struct Chol {
    l: Vec<Vec<f64>>, // row i holds L[i][0..=i]
}

impl Chol {
    fn new() -> Self {
        Chol { l: Vec::new() }
    }

    fn dim(&self) -> usize {
        self.l.len()
    }

    /// Append a new variable with cross products `g = X_Aᵀx_new` (len k) and
    /// `gamma = x_newᵀx_new`. Returns false if the new pivot is not positive
    /// (numerically dependent column).
    fn push(&mut self, g: &[f64], gamma: f64) -> bool {
        let k = self.dim();
        debug_assert_eq!(g.len(), k);
        // solve L w = g by forward substitution
        let mut w = vec![0.0; k];
        for i in 0..k {
            let mut s = g[i];
            for j in 0..i {
                s -= self.l[i][j] * w[j];
            }
            w[i] = s / self.l[i][i];
        }
        let pivot = gamma - dot(&w, &w);
        if pivot <= 1e-12 {
            return false;
        }
        w.push(pivot.sqrt());
        self.l.push(w);
        true
    }

    /// Solve G x = b (forward then backward substitution).
    fn solve(&self, b: &[f64]) -> Vec<f64> {
        let k = self.dim();
        debug_assert_eq!(b.len(), k);
        let mut y = vec![0.0; k];
        for i in 0..k {
            let mut s = b[i];
            for j in 0..i {
                s -= self.l[i][j] * y[j];
            }
            y[i] = s / self.l[i][i];
        }
        let mut x = vec![0.0; k];
        for i in (0..k).rev() {
            let mut s = y[i];
            for j in i + 1..k {
                s -= self.l[j][i] * x[j];
            }
            x[i] = s / self.l[i][i];
        }
        x
    }

    /// Rebuild from scratch for the given Gram matrix (used after drops).
    fn rebuild(gram: &[Vec<f64>]) -> Option<Chol> {
        let k = gram.len();
        let mut c = Chol::new();
        for i in 0..k {
            let g: Vec<f64> = (0..i).map(|j| gram[i][j]).collect();
            if !c.push(&g, gram[i][i]) {
                return None;
            }
        }
        Some(c)
    }
}

/// LARS-Lasso homotopy solver.
pub struct LarsSolver;

impl LassoSolver for LarsSolver {
    fn solve(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam_target: f64,
        _beta0: Option<&[f64]>, // homotopy always starts at λmax
        opts: &SolveOptions,
    ) -> SolveResult {
        let m = cols.len();
        let mut beta = vec![0.0; m];
        if m == 0 {
            return SolveResult { beta, iters: 0, gap: 0.0 };
        }
        let n = x.n_rows();

        // initial correlations c0 = Xᵀy over the subset
        let mut c0 = vec![0.0; m];
        x.xt_w_subset(cols, y, &mut c0);
        let (mut lam_cur, first) = c0
            .iter()
            .enumerate()
            .map(|(k, v)| (v.abs(), k))
            .fold((0.0, 0), |a, b| if b.0 > a.0 { b } else { a });
        if lam_cur <= lam_target {
            // target above λmax of the subset: zero solution
            return SolveResult { beta, iters: 0, gap: 0.0 };
        }

        let mut active: Vec<usize> = vec![first]; // indices into cols
        let mut signs: Vec<f64> = vec![c0[first].signum()];
        let mut in_active = vec![false; m];
        in_active[first] = true;
        let mut chol = Chol::new();
        chol.push(&[], x.col_sq_norm(cols[first]));
        let mut xty: Vec<f64> = vec![c0[first]];

        let mut steps = 0usize;
        let mut xa_u = vec![0.0; n];
        let mut xa_v = vec![0.0; n];
        let max_steps = opts.max_iters.min(4 * m + 16);

        while steps < max_steps {
            steps += 1;
            let u = chol.solve(&xty);
            let v = chol.solve(&signs);

            // X_A u and X_A v (for inactive-feature event coefficients)
            xa_u.fill(0.0);
            xa_v.fill(0.0);
            for (k, &a) in active.iter().enumerate() {
                x.col_axpy_into(cols[a], u[k], &mut xa_u);
                x.col_axpy_into(cols[a], v[k], &mut xa_v);
            }

            // next event: the largest λ < lam_cur among joins and drops
            let tol = 1e-10 * (1.0 + lam_cur);
            let mut lam_next = lam_target;
            let mut event: Option<(bool, usize, f64)> = None; // (is_join, idx, sign)

            // joins: |cⱼ(λ)| = λ with cⱼ(λ) = dⱼ + λ·aⱼ
            for k in 0..m {
                if in_active[k] {
                    continue;
                }
                let d = c0[k] - x.col_dot_w(cols[k], &xa_u);
                let a = x.col_dot_w(cols[k], &xa_v);
                for sgn in [1.0f64, -1.0] {
                    // cⱼ(λ) = d + λ·a meets the boundary sgn·λ at
                    // λ = d / (sgn − a)
                    let denom = sgn - a;
                    if denom.abs() < 1e-14 {
                        continue;
                    }
                    let cand = d / denom;
                    if cand < lam_cur - tol && cand > lam_next + tol {
                        lam_next = cand;
                        event = Some((true, k, sgn));
                    }
                }
            }

            // drops: β_k(λ) = u_k − λ·v_k = 0 ⇒ λ = u_k / v_k
            for (k, &_a) in active.iter().enumerate() {
                if v[k].abs() < 1e-14 {
                    continue;
                }
                let cand = u[k] / v[k];
                if cand < lam_cur - tol && cand > lam_next + tol {
                    lam_next = cand;
                    event = Some((false, k, 0.0));
                }
            }

            // set β at λ_next on the current segment
            for (k, &a) in active.iter().enumerate() {
                beta[a] = u[k] - lam_next * v[k];
            }
            lam_cur = lam_next;

            match event {
                None => break, // reached λ_target
                Some((true, k, sgn)) => {
                    // join feature k with sign sgn
                    let g: Vec<f64> = active
                        .iter()
                        .map(|&a| x.col_dot_col(cols[k], cols[a]))
                        .collect();
                    if chol.push(&g, x.col_sq_norm(cols[k])) {
                        active.push(k);
                        signs.push(sgn);
                        xty.push(c0[k]);
                        in_active[k] = true;
                        beta[k] = 0.0;
                    }
                    // if push failed the column is linearly dependent —
                    // skip it (its correlation cannot exceed the active ones)
                }
                Some((false, k, _)) => {
                    // drop active position k
                    let a = active.remove(k);
                    signs.remove(k);
                    xty.remove(k);
                    in_active[a] = false;
                    beta[a] = 0.0;
                    // rebuild the Cholesky for the reduced active set
                    let gram: Vec<Vec<f64>> = active
                        .iter()
                        .map(|&ai| {
                            active
                                .iter()
                                .map(|&aj| x.col_dot_col(cols[ai], cols[aj]))
                                .collect()
                        })
                        .collect();
                    match Chol::rebuild(&gram) {
                        Some(c) => chol = c,
                        None => break, // should not happen; bail safely
                    }
                    if active.is_empty() {
                        // re-seed from the current max correlation
                        let mut best = (0.0f64, usize::MAX);
                        for j in 0..m {
                            if !in_active[j] && c0[j].abs() > best.0 {
                                best = (c0[j].abs(), j);
                            }
                        }
                        if best.1 == usize::MAX || best.0 <= lam_target {
                            break;
                        }
                        let j = best.1;
                        active.push(j);
                        signs.push(c0[j].signum());
                        xty.push(c0[j]);
                        in_active[j] = true;
                        chol = Chol::new();
                        chol.push(&[], x.col_sq_norm(cols[j]));
                    }
                }
            }
        }

        // certify with the duality gap
        let mut r = y.to_vec();
        for (k, &j) in cols.iter().enumerate() {
            if beta[k] != 0.0 {
                x.col_axpy_into(j, -beta[k], &mut r);
            }
        }
        let gap = dual::duality_gap(x, y, cols, &beta, &r, lam_target);
        SolveResult { beta, iters: steps, gap }
    }

    fn name(&self) -> &'static str {
        "lars"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{ops::soft_threshold, DenseMatrix};
    use crate::solver::testutil::small_problem;
    use crate::solver::{cd::CdSolver, SolveOptions};
    use crate::util::prop;

    #[test]
    fn orthogonal_design_closed_form() {
        let n = 5;
        let mut rows = vec![vec![0.0; n]; n];
        for (i, row) in rows.iter_mut().enumerate() {
            row[i] = 1.0;
        }
        let x = DenseMatrix::from_rows(&rows);
        let y = vec![3.0, -2.0, 0.7, 0.0, -5.0];
        let cols: Vec<usize> = (0..n).collect();
        let lam = 1.0;
        let res = LarsSolver.solve(&x, &y, &cols, lam, None, &SolveOptions::default());
        for (bi, yi) in res.beta.iter().zip(y.iter()) {
            assert!((bi - soft_threshold(*yi, lam)).abs() < 1e-8, "{bi} vs {yi}");
        }
    }

    #[test]
    fn matches_cd_on_random_problems() {
        prop::check("LARS == CD objective", 0x1A45, 10, |rng| {
            let n = 10 + rng.usize(20);
            let p = 10 + rng.usize(30);
            let (x, y, lam) = small_problem(rng.next_u64(), n, p, rng.uniform(0.1, 0.8));
            let cols: Vec<usize> = (0..p).collect();
            let opts = SolveOptions { tol_gap: 1e-11, ..Default::default() };
            let b_lars = LarsSolver.solve(&x, &y, &cols, lam, None, &opts);
            let b_cd = CdSolver.solve(&x, &y, &cols, lam, None, &opts);
            let o_lars = dual::primal_objective(&x, &y, &cols, &b_lars.beta, lam);
            let o_cd = dual::primal_objective(&x, &y, &cols, &b_cd.beta, lam);
            let scale = o_cd.abs().max(1.0);
            assert!(
                (o_lars - o_cd).abs() < 1e-6 * scale,
                "lars={o_lars} cd={o_cd} gap_lars={}",
                b_lars.gap
            );
        });
    }

    #[test]
    fn gap_certificate() {
        let (x, y, lam) = small_problem(21, 40, 90, 0.25);
        let cols: Vec<usize> = (0..90).collect();
        let res = LarsSolver.solve(&x, &y, &cols, lam, None, &SolveOptions::default());
        assert!(res.gap < 1e-8, "gap={}", res.gap);
    }

    #[test]
    fn above_lambda_max_zero() {
        let (x, y, _) = small_problem(22, 20, 40, 1.0);
        let lm = dual::lambda_max(&x, &y);
        let cols: Vec<usize> = (0..40).collect();
        let res = LarsSolver.solve(&x, &y, &cols, lm * 1.1, None, &SolveOptions::default());
        assert!(res.beta.iter().all(|b| *b == 0.0));
    }
}
