//! Cyclic coordinate descent with an active-set strategy — the workhorse
//! solver, standing in for the paper's SLEP solver [22].
//!
//! Classic glmnet-style scheme: maintain the residual `r = y − Xβ`; a
//! coordinate update is `βⱼ ← S(xⱼᵀr + ‖xⱼ‖²βⱼ, λ)/‖xⱼ‖²`. After one full
//! sweep, iterate only over the current support until stationary, then do a
//! verification sweep over all columns; converged when a full sweep changes
//! nothing and the duality gap is below tolerance.
//!
//! Matrix-free: every coordinate update is one `col_dot_w` plus one
//! `col_axpy_into` through [`DesignMatrix`], so on the CSC backend an epoch
//! over the surviving columns costs O(Σ nnz(xⱼ)) — the sparse solver the
//! old `sparse_cd_solve` provided is now just this solver on a `CscMatrix`.

use super::{dual, LassoSolver, SolveOptions, SolveResult, SolverHook};
use crate::linalg::{ops::soft_threshold, DesignMatrix};

/// Cyclic CD with active-set outer loop and duality-gap stopping.
pub struct CdSolver;

impl CdSolver {
    /// One coordinate sweep over `work` (indices into `cols`), skipping
    /// positions the dynamic hook has dropped (`alive` is all-true when no
    /// hook runs, so the un-hooked trajectory is untouched). Returns the
    /// largest |Δβⱼ|·‖xⱼ‖ seen (a scale-aware progress measure).
    #[allow(clippy::too_many_arguments)]
    fn sweep(
        x: &dyn DesignMatrix,
        cols: &[usize],
        work: &[usize],
        alive: &[bool],
        sq_norms: &[f64],
        lam: f64,
        beta: &mut [f64],
        r: &mut [f64],
    ) -> f64 {
        let mut max_delta = 0.0f64;
        for &k in work {
            if !alive[k] {
                continue;
            }
            let sq = sq_norms[k];
            if sq == 0.0 {
                continue;
            }
            let old = beta[k];
            // c = xⱼᵀ r + ‖xⱼ‖² βⱼ  (partial residual correlation)
            let c = x.col_dot_w(cols[k], r) + sq * old;
            let new = soft_threshold(c, lam) / sq;
            if new != old {
                x.col_axpy_into(cols[k], old - new, r);
                beta[k] = new;
                max_delta = max_delta.max((new - old).abs() * sq.sqrt());
            }
        }
        max_delta
    }

    /// Shared body of `solve` / `solve_with_hook`. With `hook = None` the
    /// `alive` mask stays all-true and the floating-point sequence is
    /// identical to the pre-hook solver (backend_parity pins this).
    #[allow(clippy::too_many_arguments)]
    fn solve_impl(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
        mut hook: Option<&mut dyn SolverHook>,
    ) -> SolveResult {
        let m = cols.len();
        let mut beta = match beta0 {
            Some(b) => {
                assert_eq!(b.len(), m);
                b.to_vec()
            }
            None => vec![0.0; m],
        };
        // residual r = y − Xβ
        let mut r = y.to_vec();
        for (k, &j) in cols.iter().enumerate() {
            if beta[k] != 0.0 {
                x.col_axpy_into(j, -beta[k], &mut r);
            }
        }
        let sq_norms: Vec<f64> = cols.iter().map(|&j| x.col_sq_norm(j)).collect();
        let all: Vec<usize> = (0..m).collect();
        let y_scale = crate::linalg::nrm2(y).max(1.0);
        // gap-safe drop mask (hook runs only): dropped coordinates are
        // certified zero at the optimum — zero them, restore the residual,
        // and skip them in every later sweep
        let mut alive = vec![true; m];
        let mut refine = |gap: f64, alive: &mut [bool], beta: &mut [f64], r: &mut [f64]| {
            let Some(h) = hook.as_deref_mut() else { return };
            if h.refine(lam, cols, beta, r, gap, alive) == 0 {
                return;
            }
            for k in 0..m {
                // newly dropped: cleared but still carrying a coefficient
                if !alive[k] && beta[k] != 0.0 {
                    x.col_axpy_into(cols[k], beta[k], r);
                    beta[k] = 0.0;
                }
            }
        };

        // deadline-aware serving: resolve the wall-clock budget once; with
        // no budget the clock is never read (bit-identical trajectories)
        // audit:allow(determinism:clock, deadline plumbing: never read unless time_budget is Some)
        let deadline = opts.time_budget.and_then(|b| std::time::Instant::now().checked_add(b));
        // audit:allow(determinism:clock, deadline plumbing: never read unless time_budget is Some)
        let out_of_time = || deadline.is_some_and(|d| std::time::Instant::now() >= d);

        let mut gap = f64::INFINITY;
        let mut epoch = 0;
        while epoch < opts.max_iters {
            // budget check once per outer round (≈ gap_check_every epochs of
            // resolution); certify whatever iterate we have and stop
            if out_of_time() {
                gap = dual::duality_gap(x, y, cols, &beta, &r, lam);
                break;
            }
            // full verification sweep
            let delta_full =
                Self::sweep(x, cols, &all, &alive, &sq_norms, lam, &mut beta, &mut r);
            epoch += 1;
            // inner active-set sweeps — cheap, over the support only
            let support: Vec<usize> = (0..m).filter(|&k| beta[k] != 0.0).collect();
            if !support.is_empty() {
                for _ in 0..opts.gap_check_every.max(1) {
                    if epoch >= opts.max_iters {
                        break;
                    }
                    let d = Self::sweep(
                        x, cols, &support, &alive, &sq_norms, lam, &mut beta, &mut r,
                    );
                    epoch += 1;
                    if d <= 1e-12 * y_scale {
                        break;
                    }
                }
            }
            // convergence test: full-sweep stationarity + certified gap
            if delta_full <= 1e-10 * y_scale {
                gap = dual::duality_gap(x, y, cols, &beta, &r, lam);
                if gap <= opts.tol_gap || out_of_time() {
                    break;
                }
                refine(gap, &mut alive, &mut beta, &mut r);
            } else if epoch % opts.gap_check_every == 0 {
                gap = dual::duality_gap(x, y, cols, &beta, &r, lam);
                if gap <= opts.tol_gap || out_of_time() {
                    break;
                }
                refine(gap, &mut alive, &mut beta, &mut r);
            }
        }
        if gap.is_infinite() {
            gap = dual::duality_gap(x, y, cols, &beta, &r, lam);
        }
        SolveResult { beta, iters: epoch, gap }
    }
}

impl LassoSolver for CdSolver {
    fn solve(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> SolveResult {
        self.solve_impl(x, y, cols, lam, beta0, opts, None)
    }

    fn solve_with_hook(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
        hook: Option<&mut dyn SolverHook>,
    ) -> SolveResult {
        self.solve_impl(x, y, cols, lam, beta0, opts, hook)
    }

    fn name(&self) -> &'static str {
        "cd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::{axpy, dot, DenseMatrix};
    use crate::solver::testutil::small_problem;
    use crate::util::prop;

    #[test]
    fn orthogonal_design_closed_form() {
        // X = I (n=p), lasso solution is soft-threshold of y.
        let n = 6;
        let mut rows = vec![vec![0.0; n]; n];
        for (i, row) in rows.iter_mut().enumerate() {
            row[i] = 1.0;
        }
        let x = DenseMatrix::from_rows(&rows);
        let y = vec![3.0, -2.0, 0.5, -0.1, 1.0, 0.0];
        let cols: Vec<usize> = (0..n).collect();
        let lam = 1.0;
        let res = CdSolver.solve(&x, &y, &cols, lam, None, &SolveOptions::default());
        for (bi, yi) in res.beta.iter().zip(y.iter()) {
            assert!((bi - soft_threshold(*yi, lam)).abs() < 1e-9, "{bi} vs {yi}");
        }
        assert!(res.gap <= 1e-7);
    }

    #[test]
    fn zero_solution_at_lambda_max() {
        let (x, y, _) = small_problem(3, 30, 60, 1.0);
        let lm = dual::lambda_max(&x, &y);
        let cols: Vec<usize> = (0..60).collect();
        let res = CdSolver.solve(&x, &y, &cols, lm * 1.0001, None, &SolveOptions::default());
        assert!(res.beta.iter().all(|b| *b == 0.0));
    }

    #[test]
    fn gap_certified_small() {
        let (x, y, lam) = small_problem(4, 40, 100, 0.2);
        let cols: Vec<usize> = (0..100).collect();
        let res = CdSolver.solve(&x, &y, &cols, lam, None, &SolveOptions::default());
        assert!(res.gap <= 1e-7, "gap={}", res.gap);
        // KKT: |xⱼᵀr| ≤ λ(1+ε) for all j; == λ on support
        let full = res.scatter(&cols, 100);
        let mut r = y.clone();
        for (j, b) in full.iter().enumerate() {
            if *b != 0.0 {
                axpy(-b, x.col(j), &mut r);
            }
        }
        for j in 0..100 {
            let c = dot(x.col(j), &r);
            assert!(c.abs() <= lam * (1.0 + 1e-4), "KKT violated at {j}: {c} vs {lam}");
            if full[j] != 0.0 {
                assert!((c.abs() - lam).abs() <= lam * 1e-3, "support KKT at {j}");
            }
        }
    }

    #[test]
    fn warm_start_converges_faster() {
        let (x, y, lam) = small_problem(5, 50, 150, 0.3);
        let cols: Vec<usize> = (0..150).collect();
        let opts = SolveOptions::default();
        let cold = CdSolver.solve(&x, &y, &cols, lam, None, &opts);
        // warm start at a nearby λ
        let warm_src = CdSolver.solve(&x, &y, &cols, lam * 1.1, None, &opts);
        let warm = CdSolver.solve(&x, &y, &cols, lam, Some(&warm_src.beta), &opts);
        assert!(warm.iters <= cold.iters, "warm {} vs cold {}", warm.iters, cold.iters);
        assert!(warm.gap <= 1e-7);
    }

    #[test]
    fn subset_solve_matches_full_when_inactive_removed() {
        // removing truly-inactive columns must not change the solution
        let (x, y, lam) = small_problem(6, 30, 80, 0.5);
        let cols: Vec<usize> = (0..80).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let full = CdSolver.solve(&x, &y, &cols, lam, None, &opts);
        let full_beta = full.scatter(&cols, 80);
        let support: Vec<usize> = (0..80).filter(|&j| full_beta[j] != 0.0).collect();
        if support.is_empty() {
            return;
        }
        let red = CdSolver.solve(&x, &y, &support, lam, None, &opts);
        let red_beta = red.scatter(&support, 80);
        for j in 0..80 {
            assert!((full_beta[j] - red_beta[j]).abs() < 1e-5, "col {j}");
        }
    }

    #[test]
    fn randomized_kkt_property() {
        prop::check("CD satisfies KKT on random problems", 0xCD1, 15, |rng| {
            let n = 10 + rng.usize(30);
            let p = 10 + rng.usize(60);
            let ds = synthetic::synthetic2(n, p, p / 6 + 1, 0.1, rng.next_u64());
            let lam = rng.uniform(0.1, 0.9) * dual::lambda_max(&ds.x, &ds.y);
            let cols: Vec<usize> = (0..p).collect();
            let res = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &SolveOptions::default());
            assert!(res.gap <= 1e-6, "gap={}", res.gap);
        });
    }

    #[test]
    fn time_budget_stops_early_with_finite_gap() {
        let (x, y, lam) = small_problem(9, 60, 300, 0.1);
        let cols: Vec<usize> = (0..300).collect();
        // unreachable tolerance: only the budget (or max_iters) can stop it
        let opts = SolveOptions {
            tol_gap: 1e-300,
            time_budget: Some(std::time::Duration::ZERO),
            ..Default::default()
        };
        let res = CdSolver.solve(&x, &y, &cols, lam, None, &opts);
        assert!(res.gap.is_finite());
        assert!(res.gap > opts.tol_gap, "budget stop reports the achieved gap");
        assert!(res.iters < opts.max_iters, "stopped on the clock, not the cap");
        // an expired budget still yields a usable (if loose) iterate
        assert!(res.beta.iter().all(|b| b.is_finite()));
    }

    #[test]
    fn empty_column_set() {
        let (x, y, lam) = small_problem(7, 10, 20, 0.5);
        let res = CdSolver.solve(&x, &y, &[], lam, None, &SolveOptions::default());
        assert!(res.beta.is_empty());
    }
}
