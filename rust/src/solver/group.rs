//! Group-Lasso solver: block proximal (coordinate) descent.
//!
//! Problem (50): `min ½‖y − Σ_g X_g β_g‖² + λ Σ_g √n_g ‖β_g‖₂`.
//! Per block, one proximal-gradient step with block Lipschitz constant
//! `L_g = ‖X_g‖²`: `β_g ← BST(β_g + X_gᵀ r / L_g, λ√n_g / L_g)` where BST is
//! the block soft-threshold `BST(z, t) = max(0, 1 − t/‖z‖)·z`. This is the
//! standard SLEP-style block descent the paper's §4.2 substrate used.

use super::{dual, SolveOptions};
use crate::linalg::{nrm2, DesignMatrix};

/// Result of a group-Lasso solve over a subset of groups.
#[derive(Clone, Debug)]
pub struct GroupSolveResult {
    /// Per-group coefficient blocks, aligned with the `active` group list.
    pub beta: Vec<Vec<f64>>,
    pub iters: usize,
    pub gap: f64,
}

impl GroupSolveResult {
    /// Scatter back to a full-length β given the group table.
    pub fn scatter(
        &self,
        groups: &[(usize, usize)],
        active: &[usize],
        p: usize,
    ) -> Vec<f64> {
        let mut full = vec![0.0; p];
        for (k, &g) in active.iter().enumerate() {
            let (start, len) = groups[g];
            full[start..start + len].copy_from_slice(&self.beta[k]);
        }
        full
    }
}

/// Block soft-threshold: `max(0, 1 − t/‖z‖)·z` (in place).
pub fn block_soft_threshold(z: &mut [f64], t: f64) {
    let nz = nrm2(z);
    if nz <= t {
        z.fill(0.0);
    } else {
        let s = 1.0 - t / nz;
        for v in z.iter_mut() {
            *v *= s;
        }
    }
}

/// Block proximal descent over the `active` subset of `groups`.
pub struct GroupBcdSolver;

impl GroupBcdSolver {
    pub fn solve(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        groups: &[(usize, usize)],
        active: &[usize],
        lam: f64,
        beta0: Option<&[Vec<f64>]>,
        opts: &SolveOptions,
    ) -> GroupSolveResult {
        let m = active.len();
        let mut beta: Vec<Vec<f64>> = match beta0 {
            Some(b) => {
                assert_eq!(b.len(), m);
                b.to_vec()
            }
            None => active.iter().map(|&g| vec![0.0; groups[g].1]).collect(),
        };
        // residual r = y − Σ X_g β_g
        let mut r = y.to_vec();
        for (k, &g) in active.iter().enumerate() {
            let (start, len) = groups[g];
            for (c, j) in (start..start + len).enumerate() {
                if beta[k][c] != 0.0 {
                    x.col_axpy_into(j, -beta[k][c], &mut r);
                }
            }
        }
        // block Lipschitz constants L_g = ‖X_g‖² via power iteration
        let lips: Vec<f64> = active
            .iter()
            .map(|&g| {
                let (start, len) = groups[g];
                let cols: Vec<usize> = (start..start + len).collect();
                x.op_norm_sq_subset(&cols, 20, 0x9B0 + g as u64).max(1e-12)
            })
            .collect();

        let mut grad = Vec::new();
        let mut gap = f64::INFINITY;
        let mut epoch = 0;
        let y_scale = nrm2(y).max(1.0);
        while epoch < opts.max_iters {
            let mut max_delta = 0.0f64;
            for (k, &g) in active.iter().enumerate() {
                let (start, len) = groups[g];
                let lg = lips[k];
                let t = lam * (len as f64).sqrt() / lg;
                grad.clear();
                grad.resize(len, 0.0);
                // z = β_g + X_gᵀ r / L_g
                for (c, j) in (start..start + len).enumerate() {
                    grad[c] = beta[k][c] + x.col_dot_w(j, &r) / lg;
                }
                block_soft_threshold(&mut grad, t);
                // apply delta to residual
                for (c, j) in (start..start + len).enumerate() {
                    let d = grad[c] - beta[k][c];
                    if d != 0.0 {
                        x.col_axpy_into(j, -d, &mut r);
                        max_delta = max_delta.max(d.abs());
                        beta[k][c] = grad[c];
                    }
                }
            }
            epoch += 1;
            if epoch % opts.gap_check_every == 0 || max_delta <= 1e-12 * y_scale {
                let flat: Vec<f64> = beta.iter().flatten().copied().collect();
                gap = dual::group_duality_gap(x, y, groups, active, &flat, &r, lam);
                if gap <= opts.tol_gap || max_delta <= 1e-13 * y_scale {
                    break;
                }
            }
        }
        if gap.is_infinite() {
            let flat: Vec<f64> = beta.iter().flatten().copied().collect();
            gap = dual::group_duality_gap(x, y, groups, active, &flat, &r, lam);
        }
        GroupSolveResult { beta, iters: epoch, gap }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::{axpy, dot, DenseMatrix};
    use crate::solver::dual::group_lambda_max;

    fn problem(seed: u64) -> (DenseMatrix, Vec<f64>, Vec<(usize, usize)>) {
        let ds = synthetic::group_synthetic(30, 80, 16, seed);
        let g = ds.groups.clone().unwrap();
        (ds.x.into_dense(), ds.y, g)
    }

    #[test]
    fn block_soft_threshold_cases() {
        let mut z = vec![3.0, 4.0]; // norm 5
        block_soft_threshold(&mut z, 5.0);
        assert_eq!(z, vec![0.0, 0.0]);
        let mut z = vec![3.0, 4.0];
        block_soft_threshold(&mut z, 2.5);
        assert!((nrm2(&z) - 2.5).abs() < 1e-12);
        // direction preserved
        assert!((z[1] / z[0] - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_above_lambda_max() {
        let (x, y, groups) = problem(1);
        let (glm, _) = group_lambda_max(&x, &y, &groups);
        let active: Vec<usize> = (0..groups.len()).collect();
        let res = GroupBcdSolver.solve(
            &x,
            &y,
            &groups,
            &active,
            glm * 1.001,
            None,
            &SolveOptions::default(),
        );
        assert!(res.beta.iter().all(|b| b.iter().all(|v| *v == 0.0)));
    }

    #[test]
    fn gap_converges() {
        let (x, y, groups) = problem(2);
        let (glm, _) = group_lambda_max(&x, &y, &groups);
        let active: Vec<usize> = (0..groups.len()).collect();
        let res = GroupBcdSolver.solve(
            &x,
            &y,
            &groups,
            &active,
            0.3 * glm,
            None,
            &SolveOptions::default(),
        );
        assert!(res.gap <= 1e-7, "gap={}", res.gap);
        // some groups must be zero at moderate λ, some nonzero
        let zeros = res.beta.iter().filter(|b| b.iter().all(|v| *v == 0.0)).count();
        assert!(zeros > 0 && zeros < groups.len(), "zeros={zeros}");
    }

    #[test]
    fn group_kkt_conditions() {
        // eq. (53): for zero groups, ‖X_gᵀθ*‖ ≤ √n_g
        let (x, y, groups) = problem(3);
        let (glm, _) = group_lambda_max(&x, &y, &groups);
        let lam = 0.4 * glm;
        let active: Vec<usize> = (0..groups.len()).collect();
        let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
        let res = GroupBcdSolver.solve(&x, &y, &groups, &active, lam, None, &opts);
        let full = res.scatter(&groups, &active, x.n_cols());
        let mut r = y.clone();
        for (j, b) in full.iter().enumerate() {
            if *b != 0.0 {
                axpy(-b, x.col(j), &mut r);
            }
        }
        for &(start, len) in &groups {
            let mut ss = 0.0;
            for j in start..start + len {
                let d = dot(x.col(j), &r);
                ss += d * d;
            }
            let nrm = (ss).sqrt() / lam;
            assert!(nrm <= (len as f64).sqrt() * (1.0 + 1e-3), "KKT: {nrm}");
        }
    }

    #[test]
    fn warm_start_converges_not_slower() {
        let (x, y, groups) = problem(4);
        let (glm, _) = group_lambda_max(&x, &y, &groups);
        let active: Vec<usize> = (0..groups.len()).collect();
        let opts = SolveOptions::default();
        let hi = GroupBcdSolver.solve(&x, &y, &groups, &active, 0.5 * glm, None, &opts);
        let cold = GroupBcdSolver.solve(&x, &y, &groups, &active, 0.45 * glm, None, &opts);
        let warm =
            GroupBcdSolver.solve(&x, &y, &groups, &active, 0.45 * glm, Some(&hi.beta), &opts);
        assert!(warm.iters <= cold.iters + 1);
        assert!(warm.gap <= 1e-7);
    }
}
