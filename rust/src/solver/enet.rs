//! Elastic-net extension (paper §5 names generalizing the DPP family to
//! further sparse formulations as future work; the elastic net is the
//! canonical first step).
//!
//! Problem: `min ½‖y − Xβ‖² + λ‖β‖₁ + (γ/2)‖β‖²`. This is exactly a Lasso
//! on the augmented design `X̃ = [X; √γ·I], ỹ = [y; 0]`, so the whole dual-
//! polytope machinery transfers: `θ̃*(λ) = (ỹ − X̃β*)/λ` stacks the residual
//! block `r/λ` on top of `−√γ·β*/λ`, `‖x̃ᵢ‖² = ‖xᵢ‖² + γ`, and
//! `x̃ᵢᵀθ̃ = (xᵢᵀr − γβᵢ)/λ`. [`screen_enet_edpp`] evaluates EDPP on the
//! augmented geometry without ever materializing X̃.

use super::{LassoSolver, SolveOptions, SolveResult};
use crate::linalg::{dot, nrm2, ops::soft_threshold, DesignMatrix};

/// Elastic-net coordinate descent: `βⱼ ← S(xⱼᵀr + ‖xⱼ‖²βⱼ, λ)/(‖xⱼ‖² + γ)`.
pub struct EnetCdSolver {
    /// ℓ2 weight γ ≥ 0 (γ = 0 reduces to the Lasso CD solver).
    pub gamma: f64,
}

impl LassoSolver for EnetCdSolver {
    fn solve(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> SolveResult {
        let m = cols.len();
        let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; m]);
        let mut r = y.to_vec();
        for (k, &j) in cols.iter().enumerate() {
            if beta[k] != 0.0 {
                x.col_axpy_into(j, -beta[k], &mut r);
            }
        }
        let sq: Vec<f64> = cols.iter().map(|&j| x.col_sq_norm(j)).collect();
        let y_scale = nrm2(y).max(1.0);
        let mut epoch = 0;
        let mut gap = f64::INFINITY;
        while epoch < opts.max_iters {
            let mut max_delta = 0.0f64;
            for k in 0..m {
                if sq[k] == 0.0 && self.gamma == 0.0 {
                    continue;
                }
                let old = beta[k];
                let c = x.col_dot_w(cols[k], &r) + sq[k] * old;
                let new = soft_threshold(c, lam) / (sq[k] + self.gamma);
                if new != old {
                    x.col_axpy_into(cols[k], old - new, &mut r);
                    beta[k] = new;
                    max_delta = max_delta.max((new - old).abs() * (sq[k] + self.gamma).sqrt());
                }
            }
            epoch += 1;
            if max_delta <= 1e-11 * y_scale || epoch % opts.gap_check_every == 0 {
                gap = self.duality_gap(x, y, cols, &beta, &r, lam);
                if gap <= opts.tol_gap {
                    break;
                }
                if max_delta <= 1e-13 * y_scale {
                    break;
                }
            }
        }
        if gap.is_infinite() {
            gap = self.duality_gap(x, y, cols, &beta, &r, lam);
        }
        SolveResult { beta, iters: epoch, gap }
    }

    fn name(&self) -> &'static str {
        "enet-cd"
    }
}

impl EnetCdSolver {
    /// Duality gap on the augmented Lasso: residual block is `(r, −√γ·β)`.
    fn duality_gap(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        beta: &[f64],
        r: &[f64],
        lam: f64,
    ) -> f64 {
        let g = self.gamma;
        // augmented correlations: x̃ⱼᵀr̃ = xⱼᵀr − γ·βⱼ
        let mut xtr_inf = 0.0f64;
        for (k, &j) in cols.iter().enumerate() {
            xtr_inf = xtr_inf.max((x.col_dot_w(j, r) - g * beta[k]).abs());
        }
        let s = if xtr_inf <= lam || xtr_inf == 0.0 { 1.0 / lam } else { 1.0 / xtr_inf };
        let bb = dot(beta, beta);
        let rr = dot(r, r) + g * bb; // ‖r̃‖²
        let ry = dot(r, y); // ỹ has a zero tail ⇒ ⟨r̃, ỹ⟩ = ⟨r, y⟩
        let yy = dot(y, y);
        // augmented primal ½‖r̃‖² + λ‖β‖₁ (the γ/2·‖β‖² lives inside ‖r̃‖²)
        let primal = 0.5 * rr + lam * crate::linalg::nrm1(beta);
        let dist = s * s * rr - 2.0 * s / lam * ry + yy / (lam * lam);
        let dual = 0.5 * yy - 0.5 * lam * lam * dist;
        let scale = (0.5 * yy).max(1.0);
        ((primal - dual) / scale).max(0.0)
    }
}

/// EDPP screening for the elastic net on the augmented geometry. Given the
/// exact solution `beta_prev` (full length) at `lam_prev`, fills `keep` for
/// the problem at `lam`. Safe for any γ ≥ 0; γ = 0 matches Lasso EDPP.
#[allow(clippy::too_many_arguments)]
pub fn screen_enet_edpp(
    x: &dyn DesignMatrix,
    y: &[f64],
    gamma: f64,
    beta_prev: &[f64],
    lam_prev: f64,
    lam: f64,
    lam_max: f64,
    keep: &mut [bool],
) {
    let n = x.n_rows();
    let p = x.n_cols();
    assert_eq!(keep.len(), p);
    // θ̃*(λ₀) blocks: top = r/λ₀, tail = −√γ·β/λ₀ (kept implicit as β/λ₀)
    let mut r = y.to_vec();
    for j in 0..p {
        if beta_prev[j] != 0.0 {
            x.col_axpy_into(j, -beta_prev[j], &mut r);
        }
    }
    let sqg = gamma.sqrt();
    let theta_top: Vec<f64> = r.iter().map(|v| v / lam_prev).collect();
    let theta_tail: Vec<f64> = beta_prev.iter().map(|b| -sqg * b / lam_prev).collect();

    // v1 = ỹ/λ₀ − θ̃₀ (interior case; at λ₀ = λ̃max fall back to the same ray
    // since ỹ/λ₀ = θ̃₀ there makes v1 = 0 → use the argmax feature as in
    // eq. (17); the augmented argmax feature has tail √γ·e_j)
    let interior = lam_prev < lam_max * (1.0 - 1e-12);
    let (v1_top, v1_tail): (Vec<f64>, Vec<f64>) = if interior {
        (
            (0..n).map(|i| y[i] / lam_prev - theta_top[i]).collect(),
            theta_tail.iter().map(|t| -t).collect(),
        )
    } else {
        // x̃* = (x*, √γ e_*)·sign(x*ᵀy)
        let mut xty = vec![0.0; p];
        x.xt_w(y, &mut xty);
        let (mut best, mut arg) = (0.0f64, 0usize);
        for (j, v) in xty.iter().enumerate() {
            if v.abs() > best {
                best = v.abs();
                arg = j;
            }
        }
        let s = xty[arg].signum();
        let mut tail = vec![0.0; p];
        tail[arg] = s * sqg;
        let mut top = vec![0.0; n];
        x.col_into(arg, &mut top);
        for v in top.iter_mut() {
            *v *= s;
        }
        (top, tail)
    };
    // v2 = ỹ/λ − θ̃₀
    let v2_top: Vec<f64> = (0..n).map(|i| y[i] / lam - theta_top[i]).collect();
    let v2_tail: Vec<f64> = theta_tail.iter().map(|t| -t).collect();
    // v2⊥ over the stacked vectors
    let ip = dot(&v1_top, &v2_top) + dot(&v1_tail, &v2_tail);
    let v1v1 = dot(&v1_top, &v1_top) + dot(&v1_tail, &v1_tail);
    let coef = if v1v1 > 0.0 && ip >= 0.0 { ip / v1v1 } else { 0.0 };
    let perp_top: Vec<f64> =
        v2_top.iter().zip(v1_top.iter()).map(|(b, a)| b - coef * a).collect();
    let perp_tail: Vec<f64> =
        v2_tail.iter().zip(v1_tail.iter()).map(|(b, a)| b - coef * a).collect();
    let radius = 0.5
        * (dot(&perp_top, &perp_top) + dot(&perp_tail, &perp_tail)).sqrt();
    // center blocks
    let center_top: Vec<f64> =
        theta_top.iter().zip(perp_top.iter()).map(|(t, w)| t + 0.5 * w).collect();
    let center_tail: Vec<f64> =
        theta_tail.iter().zip(perp_tail.iter()).map(|(t, w)| t + 0.5 * w).collect();
    // test per feature: |x̃ⱼᵀc̃| + ρ‖x̃ⱼ‖ ≥ 1
    let mut scores = vec![0.0; p];
    x.xt_w(&center_top, &mut scores);
    for j in 0..p {
        let score = scores[j] + sqg * center_tail[j];
        let norm = (x.col_sq_norm(j) + gamma).sqrt();
        let sup = score.abs() + radius * norm;
        keep[j] = sup >= 1.0 - 1e-9 * (1.0 + sup.abs());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::{cd::CdSolver, dual};
    use crate::util::prop;

    #[test]
    fn gamma_zero_matches_lasso_cd() {
        let ds = synthetic::synthetic1(25, 60, 8, 0.1, 1);
        let cols: Vec<usize> = (0..60).collect();
        let lam = 0.3 * dual::lambda_max(&ds.x, &ds.y);
        let opts = SolveOptions { tol_gap: 1e-11, ..Default::default() };
        let a = EnetCdSolver { gamma: 0.0 }.solve(&ds.x, &ds.y, &cols, lam, None, &opts);
        let b = CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &opts);
        for (x, y) in a.beta.iter().zip(b.beta.iter()) {
            assert!((x - y).abs() < 1e-5 * (1.0 + y.abs()));
        }
    }

    #[test]
    fn ridge_term_shrinks_coefficients() {
        let ds = synthetic::synthetic1(30, 50, 6, 0.1, 2);
        let cols: Vec<usize> = (0..50).collect();
        let lam = 0.2 * dual::lambda_max(&ds.x, &ds.y);
        let opts = SolveOptions::default();
        let l1 = EnetCdSolver { gamma: 0.0 }.solve(&ds.x, &ds.y, &cols, lam, None, &opts);
        let en = EnetCdSolver { gamma: 5.0 }.solve(&ds.x, &ds.y, &cols, lam, None, &opts);
        let n1: f64 = l1.beta.iter().map(|b| b * b).sum();
        let n2: f64 = en.beta.iter().map(|b| b * b).sum();
        assert!(n2 < n1, "ridge term failed to shrink: {n2} !< {n1}");
    }

    #[test]
    fn enet_kkt_via_augmented_gap() {
        let ds = synthetic::synthetic2(30, 70, 8, 0.1, 3);
        let cols: Vec<usize> = (0..70).collect();
        let lam = 0.3 * dual::lambda_max(&ds.x, &ds.y);
        let res = EnetCdSolver { gamma: 1.0 }.solve(
            &ds.x,
            &ds.y,
            &cols,
            lam,
            None,
            &SolveOptions::default(),
        );
        assert!(res.gap <= 1e-7, "gap={}", res.gap);
    }

    #[test]
    fn enet_edpp_is_safe_randomized() {
        prop::check("enet EDPP safety", 0xE9E7, 10, |rng| {
            let n = 15 + rng.usize(20);
            let p = 20 + rng.usize(50);
            let ds = synthetic::synthetic1(n, p, p / 5 + 1, 0.1, rng.next_u64());
            let gamma = rng.uniform(0.0, 2.0);
            let lam_max = dual::lambda_max(&ds.x, &ds.y);
            let f1 = rng.uniform(0.35, 0.95);
            let f2 = rng.uniform(0.1, f1 * 0.95);
            let (lam0, lam) = (f1 * lam_max, f2 * lam_max);
            let cols: Vec<usize> = (0..p).collect();
            let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
            let solver = EnetCdSolver { gamma };
            let prev = solver.solve(&ds.x, &ds.y, &cols, lam0, None, &opts).scatter(&cols, p);
            let mut keep = vec![true; p];
            screen_enet_edpp(&ds.x, &ds.y, gamma, &prev, lam0, lam, lam_max, &mut keep);
            let exact = solver.solve(&ds.x, &ds.y, &cols, lam, None, &opts).scatter(&cols, p);
            for j in 0..p {
                if !keep[j] {
                    assert!(
                        exact[j].abs() < 1e-9,
                        "enet EDPP discarded active {j} (β={}, γ={gamma})",
                        exact[j]
                    );
                }
            }
        });
    }

    #[test]
    fn enet_edpp_rejects_effectively() {
        let ds = synthetic::synthetic1(40, 300, 15, 0.1, 5);
        let lam_max = dual::lambda_max(&ds.x, &ds.y);
        let cols: Vec<usize> = (0..300).collect();
        let opts = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let solver = EnetCdSolver { gamma: 0.5 };
        let prev = solver
            .solve(&ds.x, &ds.y, &cols, 0.5 * lam_max, None, &opts)
            .scatter(&cols, 300);
        let mut keep = vec![true; 300];
        screen_enet_edpp(&ds.x, &ds.y, 0.5, &prev, 0.5 * lam_max, 0.45 * lam_max, lam_max, &mut keep);
        let rejected = keep.iter().filter(|k| !**k).count();
        assert!(rejected > 200, "only rejected {rejected}/300");
    }
}
