//! FISTA (accelerated proximal gradient) Lasso solver.
//!
//! `β⁺ = S(w − ∇f(w)/L, λ/L)` with Nesterov momentum; L = ‖X_cols‖² from a
//! few power iterations. Matches CD to gap tolerance (see solver::tests);
//! exists both as a cross-check and because its epoch structure (two dense
//! matvecs) is what the L2 JAX `fista_epoch` artifact mirrors.

use super::{
    dual, FistaWarmState, LassoSolver, SolveOptions, SolveResult, SolverHook, SolverState,
};
use crate::linalg::{axpy, ops::soft_threshold, DesignMatrix};

/// FISTA with constant step 1/L and duality-gap stopping.
pub struct FistaSolver;

impl FistaSolver {
    /// Shared body of `solve` / `solve_with_hook` / `solve_warm`. The
    /// dynamic hook runs at gap checks; dropped coordinates are *compacted
    /// out* of the live problem (the two dense matvecs per iteration shrink
    /// with them) and momentum restarts (t = 1), which keeps the
    /// constant-step analysis valid — `lip` over the original column set
    /// upper-bounds every subset. With `hook = None` the live set never
    /// changes and the iterate sequence is identical to the pre-hook solver.
    ///
    /// `warm`, when given, carries momentum across solves: a recorded
    /// [`FistaWarmState`] matching (λ bit-for-bit, identical column subset)
    /// seeds `w`/`t` instead of the cold `w = β₀, t = 1` start — paired with
    /// a β₀ equal to the recorded exit iterate this *continues* the exact
    /// interrupted trajectory (pinned bitwise in the tests below). On exit
    /// the current state is recorded back. Without `warm` the behavior is
    /// byte-for-byte the stateless solver.
    #[allow(clippy::too_many_arguments)]
    fn solve_impl(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
        mut hook: Option<&mut dyn SolverHook>,
        mut warm: Option<&mut SolverState>,
    ) -> SolveResult {
        let m = cols.len();
        if m == 0 {
            if let Some(st) = warm {
                *st = SolverState::None;
            }
            return SolveResult { beta: vec![], iters: 0, gap: 0.0 };
        }
        let lip = x.op_norm_sq_subset(cols, 30, 0xF157A).max(1e-12) * 1.01;
        let mut beta = beta0.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; m]);
        // live problem: positions into the ORIGINAL `cols` (identity until
        // the hook drops something)
        let mut pos: Vec<usize> = (0..m).collect();
        let mut cur_cols: Vec<usize> = cols.to_vec();
        let mut w = beta.clone(); // extrapolated point
        let mut t = 1.0f64;
        // momentum-restart-aware resume: only a state recorded for exactly
        // this (λ, cols) problem may seed w/t — anything else cold-starts,
        // which is always valid
        if let Some(SolverState::Fista(fs)) = warm.as_deref() {
            if fs.lam.to_bits() == lam.to_bits() && fs.cols == cols && fs.w.len() == m {
                w.copy_from_slice(&fs.w);
                t = fs.t;
            }
        }
        let mut xw = vec![0.0; x.n_rows()]; // X·w
        let mut grad = vec![0.0; m];
        let mut r = vec![0.0; x.n_rows()];
        let mut gap = f64::INFINITY;
        let mut iters = 0;

        // deadline-aware serving: no budget ⇒ the clock is never read and
        // the iterate sequence is untouched (same discipline as CD)
        // audit:allow(determinism:clock, deadline plumbing: never read unless time_budget is Some)
        let deadline = opts.time_budget.and_then(|b| std::time::Instant::now().checked_add(b));
        // audit:allow(determinism:clock, deadline plumbing: never read unless time_budget is Some)
        let out_of_time = || deadline.is_some_and(|d| std::time::Instant::now() >= d);

        while iters < opts.max_iters {
            if out_of_time() {
                // FISTA is non-monotone, so a gap from an earlier check
                // does not certify the current iterate — force the
                // end-of-loop recompute for the β we actually return
                gap = f64::INFINITY;
                break;
            }
            let ml = cur_cols.len();
            // ∇f(w) = Xᵀ(Xw − y)
            xw.fill(0.0);
            x.accum_cols(&cur_cols, &w, &mut xw);
            for i in 0..xw.len() {
                r[i] = xw[i] - y[i];
            }
            x.xt_w_subset(&cur_cols, &r, &mut grad[..ml]);
            let beta_prev = beta.clone();
            for k in 0..ml {
                beta[k] = soft_threshold(w[k] - grad[k] / lip, lam / lip);
            }
            let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
            let mom = (t - 1.0) / t_next;
            for k in 0..ml {
                w[k] = beta[k] + mom * (beta[k] - beta_prev[k]);
            }
            t = t_next;
            iters += 1;

            if iters % opts.gap_check_every == 0 {
                // residual at β (not w)
                xw.fill(0.0);
                x.accum_cols(&cur_cols, &beta, &mut xw);
                for i in 0..r.len() {
                    r[i] = y[i] - xw[i];
                }
                gap = dual::duality_gap(x, y, &cur_cols, &beta, &r, lam);
                if gap <= opts.tol_gap || out_of_time() {
                    break;
                }
                if let Some(h) = hook.as_deref_mut() {
                    let mut keep_pos = vec![true; ml];
                    if h.refine(lam, &cur_cols, &beta, &r, gap, &mut keep_pos) > 0 {
                        // compact the live problem; momentum restarts
                        let mut np = Vec::with_capacity(ml);
                        let mut nc = Vec::with_capacity(ml);
                        let mut nb = Vec::with_capacity(ml);
                        for k in 0..ml {
                            if keep_pos[k] {
                                np.push(pos[k]);
                                nc.push(cur_cols[k]);
                                nb.push(beta[k]);
                            }
                        }
                        pos = np;
                        cur_cols = nc;
                        beta = nb;
                        w = beta.clone();
                        t = 1.0;
                    }
                }
            }
        }
        if gap.is_infinite() {
            xw.fill(0.0);
            x.accum_cols(&cur_cols, &beta, &mut xw);
            let mut rr = y.to_vec();
            axpy(-1.0, &xw, &mut rr);
            gap = dual::duality_gap(x, y, &cur_cols, &beta, &rr, lam);
        }
        // record exit state for a momentum-aware resume (the recorded cols
        // are the *live* set, so a post-compaction state only resumes a
        // matching compacted problem)
        if let Some(st) = warm {
            *st = SolverState::Fista(FistaWarmState {
                lam,
                cols: cur_cols.clone(),
                w: w.clone(),
                t,
            });
        }
        // scatter the live coefficients back to the original alignment
        if pos.len() == m {
            SolveResult { beta, iters, gap }
        } else {
            let mut full = vec![0.0; m];
            for (i, &k) in pos.iter().enumerate() {
                full[k] = beta[i];
            }
            SolveResult { beta: full, iters, gap }
        }
    }
}

impl LassoSolver for FistaSolver {
    fn solve(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> SolveResult {
        self.solve_impl(x, y, cols, lam, beta0, opts, None, None)
    }

    fn solve_with_hook(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
        hook: Option<&mut dyn SolverHook>,
    ) -> SolveResult {
        self.solve_impl(x, y, cols, lam, beta0, opts, hook, None)
    }

    fn solve_warm(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
        hook: Option<&mut dyn SolverHook>,
        state: &mut SolverState,
    ) -> SolveResult {
        self.solve_impl(x, y, cols, lam, beta0, opts, hook, Some(state))
    }

    fn name(&self) -> &'static str {
        "fista"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::testutil::small_problem;

    #[test]
    fn converges_to_gap_tolerance() {
        let (x, y, lam) = small_problem(11, 30, 60, 0.3);
        let cols: Vec<usize> = (0..60).collect();
        let res = FistaSolver.solve(&x, &y, &cols, lam, None, &SolveOptions::default());
        assert!(res.gap <= 1e-7, "gap={}", res.gap);
    }

    #[test]
    fn objective_never_worse_than_zero_vector() {
        let (x, y, lam) = small_problem(12, 25, 50, 0.2);
        let cols: Vec<usize> = (0..50).collect();
        let res = FistaSolver.solve(&x, &y, &cols, lam, None, &SolveOptions::default());
        let obj = dual::primal_objective(&x, &y, &cols, &res.beta, lam);
        let zero_obj = dual::primal_objective(&x, &y, &cols, &vec![0.0; 50], lam);
        assert!(obj <= zero_obj + 1e-9);
    }

    #[test]
    fn warm_start_respected() {
        let (x, y, lam) = small_problem(13, 20, 40, 0.4);
        let cols: Vec<usize> = (0..40).collect();
        let opts = SolveOptions { tol_gap: 1e-9, ..Default::default() };
        let a = FistaSolver.solve(&x, &y, &cols, lam, None, &opts);
        let b = FistaSolver.solve(&x, &y, &cols, lam, Some(&a.beta), &opts);
        assert!(b.iters <= a.iters);
        assert!(b.gap <= 1e-9);
    }

    #[test]
    fn empty_cols() {
        let (x, y, lam) = small_problem(14, 10, 20, 0.4);
        let res = FistaSolver.solve(&x, &y, &[], lam, None, &SolveOptions::default());
        assert_eq!(res.iters, 0);
        assert!(res.beta.is_empty());
    }

    /// The warm-state contract: an interrupted solve resumed with its
    /// recorded momentum state continues the *exact* trajectory — 30 + 30
    /// iterations through the state carrier are bit-identical to 60
    /// uninterrupted ones. A β-only warm start (cold momentum) cannot make
    /// this guarantee; the recorded w/t are what carry it.
    #[test]
    fn interrupted_resume_matches_uninterrupted_bitwise() {
        let (x, y, lam) = small_problem(15, 30, 60, 0.3);
        let cols: Vec<usize> = (0..60).collect();
        // tolerance far below what 60 iterations reach, so neither run
        // stops early and the gap checks stay aligned (both multiples of 10)
        let base = SolveOptions { tol_gap: 1e-300, gap_check_every: 10, ..Default::default() };
        let full = FistaSolver.solve(
            &x,
            &y,
            &cols,
            lam,
            None,
            &SolveOptions { max_iters: 60, ..base.clone() },
        );
        let mut state = SolverState::None;
        let first = FistaSolver.solve_warm(
            &x,
            &y,
            &cols,
            lam,
            None,
            &SolveOptions { max_iters: 30, ..base.clone() },
            None,
            &mut state,
        );
        match &state {
            SolverState::Fista(fs) => {
                assert_eq!(fs.lam.to_bits(), lam.to_bits());
                assert_eq!(fs.cols, cols);
                assert!(fs.t > 1.0, "momentum was recorded, t = {}", fs.t);
            }
            other => panic!("expected recorded FISTA state, got {other:?}"),
        }
        let resumed = FistaSolver.solve_warm(
            &x,
            &y,
            &cols,
            lam,
            Some(&first.beta),
            &SolveOptions { max_iters: 30, ..base },
            None,
            &mut state,
        );
        assert_eq!(full.beta.len(), resumed.beta.len());
        for (j, (a, b)) in full.beta.iter().zip(resumed.beta.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "feature {j}: {a} vs {b}");
        }
    }

    /// A recorded state for a different λ (or column set) must not seed the
    /// resume — mismatches cold-start and still converge.
    #[test]
    fn mismatched_state_cold_starts() {
        let (x, y, lam) = small_problem(16, 25, 50, 0.3);
        let cols: Vec<usize> = (0..50).collect();
        let opts = SolveOptions { tol_gap: 1e-8, ..Default::default() };
        let mut state = SolverState::None;
        let a = FistaSolver.solve_warm(&x, &y, &cols, lam, None, &opts, None, &mut state);
        assert!(a.gap <= 1e-8);
        // different λ: the stale state is ignored and overwritten
        let b = FistaSolver
            .solve_warm(&x, &y, &cols, 0.9 * lam, Some(&a.beta), &opts, None, &mut state);
        assert!(b.gap <= 1e-8);
        match &state {
            SolverState::Fista(fs) => assert_eq!(fs.lam.to_bits(), (0.9 * lam).to_bits()),
            other => panic!("expected FISTA state, got {other:?}"),
        }
        // the stateless entry points are unaffected by any of this
        let c = FistaSolver.solve(&x, &y, &cols, lam, None, &opts);
        let d = FistaSolver.solve(&x, &y, &cols, lam, None, &opts);
        for (u, v) in c.beta.iter().zip(d.beta.iter()) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }
}
