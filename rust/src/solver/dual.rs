//! Duality machinery for the Lasso (paper §2.1, eq. (2)–(4)) and group
//! Lasso (§3.1, eq. (51)–(53)).
//!
//! The dual feasible set is `F = {θ : |xᵢᵀθ| ≤ 1}`; the dual optimum is the
//! projection of `y/λ` onto F (eq. (6)). From any primal β with residual
//! `r = y − Xβ` we build a feasible dual point by scaling `r/λ` into F,
//! which yields the duality gap used as the solvers' stopping criterion and
//! by tests as the certificate of exactness.

use crate::linalg::{dot, nrm1, DesignMatrix};

/// Primal objective `½‖y − X[:,cols]β‖² + λ‖β‖₁`.
pub fn primal_objective(
    x: &dyn DesignMatrix,
    y: &[f64],
    cols: &[usize],
    beta: &[f64],
    lam: f64,
) -> f64 {
    let mut r = y.to_vec();
    for (k, &j) in cols.iter().enumerate() {
        if beta[k] != 0.0 {
            x.col_axpy_into(j, -beta[k], &mut r);
        }
    }
    0.5 * dot(&r, &r) + lam * nrm1(beta)
}

/// Dual objective `½‖y‖² − λ²/2·‖θ − y/λ‖²` (eq. (2)).
pub fn dual_objective(y: &[f64], theta: &[f64], lam: f64) -> f64 {
    let mut d = 0.0;
    for (t, yi) in theta.iter().zip(y.iter()) {
        let e = t - yi / lam;
        d += e * e;
    }
    0.5 * dot(y, y) - 0.5 * lam * lam * d
}

/// Scale factor that maps the residual into the dual feasible set:
/// `θ = r · s` with `s = min(1/λ, 1/‖Xᵀr‖∞ restricted to cols)` — the
/// standard feasible dual point (e.g. [16]). For the *exact* solution the
/// scaled residual equals θ*(λ) = r/λ by KKT eq. (3).
pub fn dual_scale(x: &dyn DesignMatrix, cols: &[usize], r: &[f64], lam: f64) -> f64 {
    let mut xtr_inf = 0.0f64;
    for &j in cols {
        xtr_inf = xtr_inf.max(x.col_dot_w(j, r).abs());
    }
    if xtr_inf <= lam || xtr_inf == 0.0 {
        1.0 / lam
    } else {
        1.0 / xtr_inf
    }
}

/// Duality gap of the reduced problem given the residual `r = y − X[:,cols]β`.
/// Returned *relative* to `max(1, ½‖y‖²)` so tolerances are scale-free.
pub fn duality_gap(
    x: &dyn DesignMatrix,
    y: &[f64],
    cols: &[usize],
    beta: &[f64],
    r: &[f64],
    lam: f64,
) -> f64 {
    let s = dual_scale(x, cols, r, lam);
    let primal = 0.5 * dot(r, r) + lam * nrm1(beta);
    // D(θ) with θ = s·r, expanded to avoid allocating θ:
    // ‖θ − y/λ‖² = s²‖r‖² − 2s/λ·⟨r,y⟩ + ‖y‖²/λ²
    let rr = dot(r, r);
    let ry = dot(r, y);
    let yy = dot(y, y);
    let dist = s * s * rr - 2.0 * s / lam * ry + yy / (lam * lam);
    let dual = 0.5 * yy - 0.5 * lam * lam * dist;
    let scale = (0.5 * yy).max(1.0);
    ((primal - dual) / scale).max(0.0)
}

/// Duality gap from precomputed parts — the working-set outer loop's form.
/// The loop's single complement sweep already produced the penalty-dual
/// norm (`inf_norm` = ‖Xᵀr‖∞ for the Lasso, max_g ‖X_gᵀr‖/√n_g for
/// groups) and the caller knows its penalty value (`penalty` = ‖β‖₁ resp.
/// Σ_g √n_g‖β_g‖), so no second O(nnz) sweep is paid. Same math and the
/// same `max(1, ½‖y‖²)` relative scale as [`duality_gap`] /
/// [`group_duality_gap`].
pub fn duality_gap_from_parts(
    y: &[f64],
    r: &[f64],
    penalty: f64,
    inf_norm: f64,
    lam: f64,
) -> f64 {
    let s = if inf_norm <= lam || inf_norm == 0.0 { 1.0 / lam } else { 1.0 / inf_norm };
    let rr = dot(r, r);
    let ry = dot(r, y);
    let yy = dot(y, y);
    let primal = 0.5 * rr + lam * penalty;
    let dist = s * s * rr - 2.0 * s / lam * ry + yy / (lam * lam);
    let dual = 0.5 * yy - 0.5 * lam * lam * dist;
    let scale = (0.5 * yy).max(1.0);
    ((primal - dual) / scale).max(0.0)
}

/// The exact dual optimum at λ from the exact primal solution:
/// `θ*(λ) = (y − Xβ*(λ))/λ` (KKT eq. (3)). Screening rules consume this.
pub fn dual_point_from_beta(
    x: &dyn DesignMatrix,
    y: &[f64],
    cols: &[usize],
    beta: &[f64],
    lam: f64,
) -> Vec<f64> {
    let mut theta = y.to_vec();
    for (k, &j) in cols.iter().enumerate() {
        if beta[k] != 0.0 {
            x.col_axpy_into(j, -beta[k], &mut theta);
        }
    }
    for t in theta.iter_mut() {
        *t /= lam;
    }
    theta
}

/// λmax = ‖Xᵀy‖∞ (eq. (7)): the smallest λ with β*(λ) = 0.
pub fn lambda_max(x: &dyn DesignMatrix, y: &[f64]) -> f64 {
    let mut scores = vec![0.0; x.n_cols()];
    x.xt_w(y, &mut scores);
    scores.iter().fold(0.0f64, |m, v| m.max(v.abs()))
}

/// argmax index for λmax — the feature `x*` of eq. (17).
pub fn lambda_max_arg(x: &dyn DesignMatrix, y: &[f64]) -> (f64, usize) {
    let mut scores = vec![0.0; x.n_cols()];
    x.xt_w(y, &mut scores);
    let mut best = (0.0f64, 0usize);
    for (j, s) in scores.iter().enumerate() {
        if s.abs() > best.0 {
            best = (s.abs(), j);
        }
    }
    best
}

/// Group-Lasso λmax = max_g ‖X_gᵀ y‖₂/√n_g (eq. (55)) with its argmax group.
pub fn group_lambda_max(
    x: &dyn DesignMatrix,
    y: &[f64],
    groups: &[(usize, usize)],
) -> (f64, usize) {
    let mut best = (0.0f64, 0usize);
    for (g, &(start, len)) in groups.iter().enumerate() {
        let mut ss = 0.0;
        for j in start..start + len {
            let d = x.col_dot_w(j, y);
            ss += d * d;
        }
        let v = (ss / len as f64).sqrt();
        if v > best.0 {
            best = (v, g);
        }
    }
    best
}

/// Group-Lasso duality gap (problem (50)/(51)), given residual r.
pub fn group_duality_gap(
    x: &dyn DesignMatrix,
    y: &[f64],
    groups: &[(usize, usize)],
    active: &[usize],
    beta: &[f64],
    r: &[f64],
    lam: f64,
) -> f64 {
    // dual scale: bring r into {θ : ‖X_gᵀθ‖ ≤ √n_g} after the /λ scaling
    let mut max_ratio = 0.0f64;
    for &g in active {
        let (start, len) = groups[g];
        let mut ss = 0.0;
        for j in start..start + len {
            let d = x.col_dot_w(j, r);
            ss += d * d;
        }
        max_ratio = max_ratio.max((ss / len as f64).sqrt());
    }
    let s = if max_ratio <= lam || max_ratio == 0.0 { 1.0 / lam } else { 1.0 / max_ratio };
    // primal: ½‖r‖² + λ Σ_g √n_g ‖β_g‖
    let mut pen = 0.0;
    let mut off = 0;
    for &g in active {
        let (_, len) = groups[g];
        let bg = &beta[off..off + len];
        pen += (len as f64).sqrt() * dot(bg, bg).sqrt();
        off += len;
    }
    let rr = dot(r, r);
    let ry = dot(r, y);
    let yy = dot(y, y);
    let primal = 0.5 * rr + lam * pen;
    let dist = s * s * rr - 2.0 * s / lam * ry + yy / (lam * lam);
    let dual = 0.5 * yy - 0.5 * lam * lam * dist;
    let scale = (0.5 * yy).max(1.0);
    ((primal - dual) / scale).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::prop;

    #[test]
    fn lambda_max_gives_zero_solution_threshold() {
        let ds = synthetic::synthetic1(30, 50, 5, 0.1, 1);
        let cols: Vec<usize> = (0..50).collect();
        let (lm, arg) = lambda_max_arg(&ds.x, &ds.y);
        assert!((lambda_max(&ds.x, &ds.y) - lm).abs() < 1e-12);
        assert!(arg < 50);
        // at λ = λmax the zero vector has zero duality gap
        let beta = vec![0.0; 50];
        let gap = duality_gap(&ds.x, &ds.y, &cols, &beta, &ds.y, lm);
        assert!(gap < 1e-10, "gap={gap}");
        // slightly below λmax, zero is no longer optimal
        let gap2 = duality_gap(&ds.x, &ds.y, &cols, &beta, &ds.y, 0.5 * lm);
        assert!(gap2 > 1e-8, "gap2={gap2}");
    }

    #[test]
    fn weak_duality_randomized() {
        // gap ≥ 0 for arbitrary (β, λ) — weak duality
        prop::check("weak duality", 0xD1, 30, |rng| {
            let n = 5 + rng.usize(20);
            let p = 5 + rng.usize(30);
            let ds = synthetic::synthetic1(n, p, p / 4, 0.1, rng.next_u64());
            let cols: Vec<usize> = (0..p).collect();
            let mut beta = vec![0.0; p];
            for b in beta.iter_mut() {
                if rng.f64() < 0.2 {
                    *b = rng.uniform(-1.0, 1.0);
                }
            }
            let mut r = ds.y.clone();
            for (k, &j) in cols.iter().enumerate() {
                crate::linalg::axpy(-beta[k], ds.x.dense().unwrap().col(j), &mut r);
            }
            let lam = rng.uniform(0.05, 1.0) * lambda_max(&ds.x, &ds.y);
            let gap = duality_gap(&ds.x, &ds.y, &cols, &beta, &r, lam);
            assert!(gap >= 0.0);
        });
    }

    #[test]
    fn gap_from_parts_matches_duality_gap() {
        // the precomputed-parts form is the same formula with the sweep
        // hoisted out — on identical inputs it must agree to round-off
        prop::check("gap_from_parts == duality_gap", 0xD7, 20, |rng| {
            let n = 5 + rng.usize(15);
            let p = 5 + rng.usize(25);
            let ds = synthetic::synthetic1(n, p, p / 4, 0.1, rng.next_u64());
            let cols: Vec<usize> = (0..p).collect();
            let mut beta = vec![0.0; p];
            for b in beta.iter_mut() {
                if rng.f64() < 0.3 {
                    *b = rng.uniform(-1.0, 1.0);
                }
            }
            let mut r = ds.y.clone();
            for (k, &j) in cols.iter().enumerate() {
                crate::linalg::axpy(-beta[k], ds.x.dense().unwrap().col(j), &mut r);
            }
            let lam = rng.uniform(0.05, 1.0) * lambda_max(&ds.x, &ds.y);
            let mut xtr_inf = 0.0f64;
            for &j in &cols {
                xtr_inf = xtr_inf.max(ds.x.col_dot_w(j, &r).abs());
            }
            let a = duality_gap(&ds.x, &ds.y, &cols, &beta, &r, lam);
            let b = duality_gap_from_parts(&ds.y, &r, nrm1(&beta), xtr_inf, lam);
            assert!((a - b).abs() <= 1e-12 * (1.0 + a.abs()), "{a} vs {b}");
        });
    }

    #[test]
    fn dual_point_matches_kkt_at_lambda_max() {
        // θ*(λmax) = y/λmax (eq. 9)
        let ds = synthetic::synthetic1(20, 40, 4, 0.1, 2);
        let lm = lambda_max(&ds.x, &ds.y);
        let theta = dual_point_from_beta(&ds.x, &ds.y, &[], &[], lm);
        for (t, yi) in theta.iter().zip(ds.y.iter()) {
            assert!((t - yi / lm).abs() < 1e-12);
        }
    }

    #[test]
    fn dual_scale_feasibility() {
        prop::check("scaled residual is dual feasible", 0xD2, 30, |rng| {
            let n = 5 + rng.usize(15);
            let p = 5 + rng.usize(25);
            let ds = synthetic::synthetic1(n, p, 3, 0.1, rng.next_u64());
            let cols: Vec<usize> = (0..p).collect();
            let lam = rng.uniform(0.05, 1.0) * lambda_max(&ds.x, &ds.y);
            let s = dual_scale(&ds.x, &cols, &ds.y, lam);
            for &j in &cols {
                let v = dot(ds.x.dense().unwrap().col(j), &ds.y) * s;
                assert!(v.abs() <= 1.0 + 1e-10, "infeasible: {v}");
            }
        });
    }

    #[test]
    fn group_lambda_max_consistency() {
        // with singleton groups, group λmax == lasso λmax
        let ds = synthetic::synthetic1(20, 30, 3, 0.1, 5);
        let groups: Vec<(usize, usize)> = (0..30).map(|j| (j, 1)).collect();
        let (glm, _) = group_lambda_max(&ds.x, &ds.y, &groups);
        assert!((glm - lambda_max(&ds.x, &ds.y)).abs() < 1e-10);
    }

    #[test]
    fn group_gap_zero_at_lambda_max() {
        let ds = synthetic::group_synthetic(25, 60, 12, 6);
        let groups = ds.groups.clone().unwrap();
        let (glm, _) = group_lambda_max(&ds.x, &ds.y, &groups);
        let active: Vec<usize> = (0..groups.len()).collect();
        let beta = vec![0.0; 60];
        let gap =
            group_duality_gap(&ds.x, &ds.y, &groups, &active, &beta, &ds.y, glm);
        assert!(gap < 1e-10, "gap={gap}");
    }
}
