//! Lasso / group-Lasso solver substrates.
//!
//! The paper treats the solver as a black box ("the screening methods can be
//! integrated with any existing solvers", §1). We provide three exact Lasso
//! solvers — coordinate descent ([`cd`], playing the role of the paper's
//! SLEP solver [22]), FISTA ([`fista`]), and LARS ([`lars`], the §4.1.2
//! "EDPP with LARS" experiments) — plus block proximal descent for group
//! Lasso ([`group`]). All first-order solvers stop on the duality gap
//! ([`dual`]), so "exact solution" means gap ≤ `tol_gap`.
//!
//! Solvers operate on a **column subset** of the full matrix (the features
//! that survived screening) without copying: the reduced problem is just an
//! index list, and every solver is **matrix-free** — it sees the design
//! matrix only through [`DesignMatrix`] (DESIGN.md §2), so one solver
//! implementation serves the dense and CSC backends. On CSC a CD epoch
//! costs O(Σ nnz of the surviving columns) instead of O(N·|cols|).

pub mod cd;
pub mod dual;
pub mod enet;
pub mod fista;
pub mod group;
pub mod lars;

use crate::linalg::DesignMatrix;

/// Convergence options shared by all iterative solvers.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Hard cap on epochs/iterations.
    pub max_iters: usize,
    /// Duality-gap stopping threshold (relative to ½‖y‖²).
    pub tol_gap: f64,
    /// Check the gap every this many epochs (gap costs one Xᵀr sweep).
    pub gap_check_every: usize,
    /// Wall-clock budget for one solve, checked at the duality-gap checks
    /// (deadline-aware serving, DESIGN.md §4). When the budget runs out the
    /// solver stops with its best gap-certified iterate — callers read the
    /// achieved `SolveResult::gap` to decide whether the answer is partial.
    /// `None` (the default) is bit-identical to the unbudgeted solver: no
    /// clock is read and the iterate sequence is untouched. First-order
    /// solvers (CD, FISTA) honor the budget; LARS takes finitely many
    /// kink steps and ignores it.
    pub time_budget: Option<std::time::Duration>,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_iters: 20_000,
            tol_gap: 1e-7,
            gap_check_every: 10,
            time_budget: None,
        }
    }
}

/// Outcome of a (reduced-problem) solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Coefficients aligned with the `cols` passed to the solver.
    pub beta: Vec<f64>,
    /// Epochs (CD) / iterations (FISTA) / steps (LARS) performed.
    pub iters: usize,
    /// Final relative duality gap.
    pub gap: f64,
}

impl SolveResult {
    /// Scatter the reduced solution back to a full-length β.
    pub fn scatter(&self, cols: &[usize], p: usize) -> Vec<f64> {
        assert_eq!(cols.len(), self.beta.len());
        let mut full = vec![0.0; p];
        for (k, &j) in cols.iter().enumerate() {
            full[j] = self.beta[k];
        }
        full
    }
}

/// In-solver dynamic-screening hook (gap-safe screening): the solver calls
/// it at its duality-gap checks with the current reduced-problem state.
///
/// `keep_pos` is aligned with `cols`; entries already false were dropped at
/// an earlier check and must be skipped. The hook may only *clear* entries
/// — each cleared position must be certified zero in the exact solution
/// (the solver then zeroes the coefficient and restores the residual, so
/// the final answer is unchanged). `beta` and `r = y − X[:,cols]·β`
/// describe the current iterate; `gap` is the solver's latest *relative*
/// duality gap. Returns the number of newly cleared positions.
pub trait SolverHook {
    fn refine(
        &mut self,
        lam: f64,
        cols: &[usize],
        beta: &[f64],
        r: &[f64],
        gap: f64,
        keep_pos: &mut [bool],
    ) -> usize;
}

/// A Lasso solver over a column-subset problem
/// `min ½‖y − X[:,cols]·β‖² + λ‖β‖₁`, generic over the matrix backend.
pub trait LassoSolver {
    /// `beta0` (if given) must be aligned with `cols` and is used as a warm
    /// start where the algorithm supports it.
    fn solve(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> SolveResult;

    /// Like [`LassoSolver::solve`] but with an optional in-iteration
    /// dynamic-screening hook. Coordinates the hook certifies are dropped
    /// mid-solve (their epochs are no longer paid) and come back as exact
    /// zeros in the returned `beta`, still aligned with `cols`. With
    /// `hook = None` this is *identical* to [`LassoSolver::solve`] — same
    /// floating-point sequence, same iterate trajectory. Default
    /// implementation ignores the hook (LARS has no gap-checked iterates).
    fn solve_with_hook(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
        hook: Option<&mut dyn SolverHook>,
    ) -> SolveResult {
        let _ = hook;
        self.solve(x, y, cols, lam, beta0, opts)
    }

    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::data::synthetic;
    use crate::linalg::DenseMatrix;

    /// Random small problem + a λ at the given fraction of λmax.
    pub fn small_problem(
        seed: u64,
        n: usize,
        p: usize,
        frac: f64,
    ) -> (DenseMatrix, Vec<f64>, f64) {
        let ds = synthetic::synthetic1(n, p, p / 5, 0.1, seed);
        let x = ds.x.into_dense();
        let mut scores = vec![0.0; p];
        x.gemv_t(&ds.y, &mut scores);
        let lam_max = scores.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        (x, ds.y, frac * lam_max)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::small_problem;
    use super::*;
    use crate::solver::{cd::CdSolver, dual, fista::FistaSolver, lars::LarsSolver};

    #[test]
    fn scatter_roundtrip() {
        let r = SolveResult { beta: vec![1.0, -2.0], iters: 1, gap: 0.0 };
        let full = r.scatter(&[3, 0], 5);
        assert_eq!(full, vec![-2.0, 0.0, 0.0, 1.0, 0.0]);
    }

    /// The paper's premise: any exact solver yields the same solution.
    /// CD, FISTA and LARS must agree on random problems to gap tolerance.
    #[test]
    fn solvers_cross_agree() {
        for seed in [1u64, 2, 3] {
            let (x, y, lam) = small_problem(seed, 40, 80, 0.3);
            let cols: Vec<usize> = (0..x.n_cols()).collect();
            let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
            let b_cd = CdSolver.solve(&x, &y, &cols, lam, None, &opts).beta;
            let b_fi = FistaSolver.solve(&x, &y, &cols, lam, None, &opts).beta;
            let b_la = LarsSolver.solve(&x, &y, &cols, lam, None, &opts).beta;
            let obj = |b: &[f64]| dual::primal_objective(&x, &y, &cols, b, lam);
            let (o_cd, o_fi, o_la) = (obj(&b_cd), obj(&b_fi), obj(&b_la));
            let scale = o_cd.abs().max(1.0);
            assert!((o_cd - o_fi).abs() < 1e-6 * scale, "cd={o_cd} fista={o_fi}");
            assert!((o_cd - o_la).abs() < 1e-6 * scale, "cd={o_cd} lars={o_la}");
        }
    }
}
