//! Lasso / group-Lasso solver substrates.
//!
//! The paper treats the solver as a black box ("the screening methods can be
//! integrated with any existing solvers", §1). We provide three exact Lasso
//! solvers — coordinate descent ([`cd`], playing the role of the paper's
//! SLEP solver [22]), FISTA ([`fista`]), and LARS ([`lars`], the §4.1.2
//! "EDPP with LARS" experiments) — plus block proximal descent for group
//! Lasso ([`group`]). All first-order solvers stop on the duality gap
//! ([`dual`]), so "exact solution" means gap ≤ `tol_gap`.
//!
//! Solvers operate on a **column subset** of the full matrix (the features
//! that survived screening) without copying: the reduced problem is just an
//! index list, and every solver is **matrix-free** — it sees the design
//! matrix only through [`DesignMatrix`] (DESIGN.md §2), so one solver
//! implementation serves the dense and CSC backends. On CSC a CD epoch
//! costs O(Σ nnz of the surviving columns) instead of O(N·|cols|).

pub mod cd;
pub mod dual;
pub mod enet;
pub mod fista;
pub mod group;
pub mod lars;
pub mod working_set;

use crate::linalg::DesignMatrix;

/// Convergence options shared by all iterative solvers.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Hard cap on epochs/iterations.
    pub max_iters: usize,
    /// Duality-gap stopping threshold (relative to ½‖y‖²).
    pub tol_gap: f64,
    /// Check the gap every this many epochs (gap costs one Xᵀr sweep).
    pub gap_check_every: usize,
    /// Wall-clock budget for one solve, checked at the duality-gap checks
    /// (deadline-aware serving, DESIGN.md §4). When the budget runs out the
    /// solver stops with its best gap-certified iterate — callers read the
    /// achieved `SolveResult::gap` to decide whether the answer is partial.
    /// `None` (the default) is bit-identical to the unbudgeted solver: no
    /// clock is read and the iterate sequence is untouched. First-order
    /// solvers (CD, FISTA) honor the budget; LARS takes finitely many
    /// kink steps and ignores it.
    pub time_budget: Option<std::time::Duration>,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_iters: 20_000,
            tol_gap: 1e-7,
            gap_check_every: 10,
            time_budget: None,
        }
    }
}

/// Outcome of a (reduced-problem) solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// Coefficients aligned with the `cols` passed to the solver.
    pub beta: Vec<f64>,
    /// Epochs (CD) / iterations (FISTA) / steps (LARS) performed.
    pub iters: usize,
    /// Final relative duality gap.
    pub gap: f64,
}

impl SolveResult {
    /// Scatter the reduced solution back to a full-length β.
    pub fn scatter(&self, cols: &[usize], p: usize) -> Vec<f64> {
        assert_eq!(cols.len(), self.beta.len());
        let mut full = vec![0.0; p];
        for (k, &j) in cols.iter().enumerate() {
            full[j] = self.beta[k];
        }
        full
    }
}

/// Cross-solve solver state carried by a warm-start cache (the serving
/// sessions in [`crate::coordinator::registry`] keep one per session):
/// whatever a solver needs, beyond β itself, to *continue* rather than
/// restart. A β-only warm start hands FISTA the right point but cold
/// momentum (t = 1), so a resumed session replays the slow early
/// iterations; [`FistaWarmState`] carries the extrapolation state so an
/// interrupted solve resumes its exact trajectory.
///
/// The state is solver-tagged: [`LassoSolver::solve_warm`]'s default
/// implementation resets it to [`SolverState::None`], so a solver that
/// keeps no state can never leave another solver's stale state behind.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum SolverState {
    /// No recorded state — resume is a plain (β-only) warm start.
    #[default]
    None,
    /// FISTA momentum state at exit ([`fista::FistaSolver`]).
    Fista(FistaWarmState),
}

/// FISTA's resume state: the extrapolated point and momentum scalar at the
/// moment the previous solve stopped, tagged with the (λ, column-subset)
/// problem they belong to. [`fista::FistaSolver`] resumes from it only when
/// λ matches bit-for-bit and the column subset is identical — anything else
/// falls back to a cold (t = 1) start, which is always valid.
#[derive(Clone, Debug, PartialEq)]
pub struct FistaWarmState {
    /// λ of the recorded solve (resume requires bit-equality).
    pub lam: f64,
    /// The live column subset at exit (after any dynamic-screening
    /// compaction), in solver order.
    pub cols: Vec<usize>,
    /// Extrapolated point w, aligned with `cols`.
    pub w: Vec<f64>,
    /// Momentum scalar t (t = 1 is a cold start).
    pub t: f64,
}

/// In-solver dynamic-screening hook (gap-safe screening): the solver calls
/// it at its duality-gap checks with the current reduced-problem state.
///
/// `keep_pos` is aligned with `cols`; entries already false were dropped at
/// an earlier check and must be skipped. The hook may only *clear* entries
/// — each cleared position must be certified zero in the exact solution
/// (the solver then zeroes the coefficient and restores the residual, so
/// the final answer is unchanged). `beta` and `r = y − X[:,cols]·β`
/// describe the current iterate; `gap` is the solver's latest *relative*
/// duality gap. Returns the number of newly cleared positions.
pub trait SolverHook {
    fn refine(
        &mut self,
        lam: f64,
        cols: &[usize],
        beta: &[f64],
        r: &[f64],
        gap: f64,
        keep_pos: &mut [bool],
    ) -> usize;
}

/// A Lasso solver over a column-subset problem
/// `min ½‖y − X[:,cols]·β‖² + λ‖β‖₁`, generic over the matrix backend.
pub trait LassoSolver {
    /// `beta0` (if given) must be aligned with `cols` and is used as a warm
    /// start where the algorithm supports it.
    fn solve(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
    ) -> SolveResult;

    /// Like [`LassoSolver::solve`] but with an optional in-iteration
    /// dynamic-screening hook. Coordinates the hook certifies are dropped
    /// mid-solve (their epochs are no longer paid) and come back as exact
    /// zeros in the returned `beta`, still aligned with `cols`. With
    /// `hook = None` this is *identical* to [`LassoSolver::solve`] — same
    /// floating-point sequence, same iterate trajectory. Default
    /// implementation ignores the hook (LARS has no gap-checked iterates).
    fn solve_with_hook(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
        hook: Option<&mut dyn SolverHook>,
    ) -> SolveResult {
        let _ = hook;
        self.solve(x, y, cols, lam, beta0, opts)
    }

    /// Like [`LassoSolver::solve_with_hook`] but threading a caller-owned
    /// [`SolverState`] through the solve: the solver may *resume* from a
    /// matching recorded state (instead of warm-starting cold) and records
    /// its exit state back into `state` for the next call. Default
    /// implementation keeps no state — it resets `state` to
    /// [`SolverState::None`] (so stale state from another solver never
    /// survives a solver switch) and delegates; the iterate sequence is
    /// identical to [`LassoSolver::solve_with_hook`].
    #[allow(clippy::too_many_arguments)]
    fn solve_warm(
        &self,
        x: &dyn DesignMatrix,
        y: &[f64],
        cols: &[usize],
        lam: f64,
        beta0: Option<&[f64]>,
        opts: &SolveOptions,
        hook: Option<&mut dyn SolverHook>,
        state: &mut SolverState,
    ) -> SolveResult {
        *state = SolverState::None;
        self.solve_with_hook(x, y, cols, lam, beta0, opts, hook)
    }

    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::data::synthetic;
    use crate::linalg::DenseMatrix;

    /// Random small problem + a λ at the given fraction of λmax.
    pub fn small_problem(
        seed: u64,
        n: usize,
        p: usize,
        frac: f64,
    ) -> (DenseMatrix, Vec<f64>, f64) {
        let ds = synthetic::synthetic1(n, p, p / 5, 0.1, seed);
        let x = ds.x.into_dense();
        let mut scores = vec![0.0; p];
        x.gemv_t(&ds.y, &mut scores);
        let lam_max = scores.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        (x, ds.y, frac * lam_max)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::small_problem;
    use super::*;
    use crate::solver::{cd::CdSolver, dual, fista::FistaSolver, lars::LarsSolver};

    #[test]
    fn scatter_roundtrip() {
        let r = SolveResult { beta: vec![1.0, -2.0], iters: 1, gap: 0.0 };
        let full = r.scatter(&[3, 0], 5);
        assert_eq!(full, vec![-2.0, 0.0, 0.0, 1.0, 0.0]);
    }

    /// The paper's premise: any exact solver yields the same solution.
    /// CD, FISTA and LARS must agree on random problems to gap tolerance.
    #[test]
    fn solvers_cross_agree() {
        for seed in [1u64, 2, 3] {
            let (x, y, lam) = small_problem(seed, 40, 80, 0.3);
            let cols: Vec<usize> = (0..x.n_cols()).collect();
            let opts = SolveOptions { tol_gap: 1e-10, ..Default::default() };
            let b_cd = CdSolver.solve(&x, &y, &cols, lam, None, &opts).beta;
            let b_fi = FistaSolver.solve(&x, &y, &cols, lam, None, &opts).beta;
            let b_la = LarsSolver.solve(&x, &y, &cols, lam, None, &opts).beta;
            let obj = |b: &[f64]| dual::primal_objective(&x, &y, &cols, b, lam);
            let (o_cd, o_fi, o_la) = (obj(&b_cd), obj(&b_fi), obj(&b_la));
            let scale = o_cd.abs().max(1.0);
            assert!((o_cd - o_fi).abs() < 1e-6 * scale, "cd={o_cd} fista={o_fi}");
            assert!((o_cd - o_la).abs() < 1e-6 * scale, "cd={o_cd} lars={o_la}");
        }
    }
}
