//! Working-set solve engine (ROADMAP item 3, "Gap Safe ++"): grow the
//! restricted problem from a screening seed instead of shrinking from p.
//!
//! Screening (DPP/EDPP) works *down* from all p features; the fastest path
//! solvers invert the direction (Fercoq–Gramfort–Salmon '15, Zeng '17, the
//! `GAPSAFE_pp` "active warm start" variants): seed a working set W from the
//! pipeline survivors plus the session's cached active set, solve the
//! W-restricted subproblem with any inner [`LassoSolver`] to a *scaled*
//! inner gap tolerance, then pay one O(nnz) sweep that serves three purposes
//! at once — KKT violator detection on W's complement, the global ‖Xᵀr‖∞
//! dual scale, and the **full-problem** duality gap
//! ([`kkt_sweep_scored`] + [`dual::duality_gap_from_parts`]). If the full
//! gap certifies (≤ `tol_gap`) the answer is exact-to-tolerance on the
//! original p-dimensional problem — never heuristic; otherwise the worst
//! violators join W in doubling batches and the loop repeats. Termination is
//! structural: W grows monotonically (bounded by p) and a KKT-clean
//! complement plus a tightened inner solve drives the full gap to zero.
//!
//! [`WorkingSetState`] is the *active warm start*: the accumulated working
//! set, the full-length β and the inner solver's momentum state survive
//! across λ steps **and** across serving requests (the session registry in
//! [`crate::coordinator::registry`] keeps one per session), so a
//! repeat-`FitPath`/`Screen` tenant pays O(active set), not O(p), per λ —
//! its first complement sweep finds no violators and certifies immediately.

use crate::linalg::DesignMatrix;
use crate::screening::strong::kkt_sweep_scored;
use crate::screening::ScreenContext;

use super::{dual, LassoSolver, SolveOptions, SolverState};

/// Outer-loop safety valve: W grows every round it fails to certify, so on
/// any real problem the loop ends long before this; the cap only bounds
/// pathological non-convergence of the *inner* solver (e.g. `max_iters` far
/// too small), where each round still makes warm-started progress.
const MAX_ROUNDS: usize = 64;

/// The active warm start a working-set caller carries across solves: the
/// accumulated working set, the last certified full-length β, and the inner
/// solver's resume state. `Default` is the cold start (empty set, zero β).
#[derive(Clone, Debug, Default)]
pub struct WorkingSetState {
    /// Accumulated working set (sorted ascending, deduped): the union of
    /// every coordinate ever admitted, so a later solve at any λ seeds a
    /// superset of every active set seen so far.
    pub cols: Vec<usize>,
    /// Full-length β from the last solve (support ⊆ `cols`); gathered as
    /// the restricted warm start of the next solve.
    pub beta: Vec<f64>,
    /// Inner-solver resume state (FISTA momentum); [`SolverState::None`]
    /// for stateless solvers.
    pub solver_state: SolverState,
}

impl WorkingSetState {
    /// Drop everything — the next solve is a cold start.
    pub fn reset(&mut self) {
        self.cols.clear();
        self.beta.clear();
        self.solver_state = SolverState::None;
    }
}

/// Outcome of one certified working-set solve.
#[derive(Clone, Debug)]
pub struct WorkingSetResult {
    /// Full-length solution (exact-to-tolerance on the *full* problem when
    /// `gap ≤ tol_gap`).
    pub beta: Vec<f64>,
    /// Total inner-solver iterations across all outer rounds.
    pub iters: usize,
    /// Final **full-problem** relative duality gap (same scale as
    /// [`dual::duality_gap`]).
    pub gap: f64,
    /// Final working-set size |W| — how much of p this λ actually touched.
    pub working_set_size: usize,
    /// Complement KKT sweeps paid (≥ 1: every certification is a sweep).
    pub kkt_passes: usize,
    /// Expansion rounds (sweeps that found violators and grew W).
    pub expansions: usize,
}

/// Solve `min ½‖y − Xβ‖² + λ‖β‖₁` over the **full** problem by growing a
/// working set from `seed_keep` (the screening pipeline's survivor mask)
/// and `state` (the caller's accumulated active set).
///
/// The returned β is certified against the full-problem duality gap — the
/// screen seed is only a guess here, so an unsafe (heuristic) or even empty
/// seed still yields a correct answer; it just costs more expansion rounds.
/// Under a `time_budget` the loop stops after the first inner solve that
/// exhausts its budget, returning its best gap-tagged iterate (same anytime
/// contract as the inner solvers).
pub fn solve_working_set(
    ctx: &ScreenContext,
    lam: f64,
    seed_keep: &[bool],
    solver: &dyn LassoSolver,
    opts: &SolveOptions,
    state: &mut WorkingSetState,
) -> WorkingSetResult {
    let x = ctx.x;
    let y = ctx.y;
    let p = x.n_cols();
    assert_eq!(seed_keep.len(), p);
    if state.beta.len() != p {
        // fresh session (or the dataset changed shape): cold start
        state.reset();
        state.beta.resize(p, 0.0);
    }

    // W₀ = screening survivors ∪ the accumulated active set
    let mut in_ws = seed_keep.to_vec();
    for &j in &state.cols {
        in_ws[j] = true;
    }
    let mut ws: Vec<usize> = (0..p).filter(|&j| in_ws[j]).collect();

    // the restricted subproblems run at a tightened tolerance so their
    // leftover slack cannot by itself push the full gap past `tol_gap`
    let mut inner = opts.clone();
    inner.tol_gap = 0.5 * opts.tol_gap;

    let mut beta_full = vec![0.0; p];
    let mut r = vec![0.0; y.len()];
    let mut iters = 0usize;
    let mut kkt_passes = 0usize;
    let mut expansions = 0usize;
    let mut gap = f64::INFINITY;
    let mut batch = 8usize;

    for _round in 0..MAX_ROUNDS {
        // ---- restricted solve over W (empty W: β = 0, r = y) ----
        let mut budget_hit = false;
        if ws.is_empty() {
            beta_full.fill(0.0);
            r.copy_from_slice(y);
        } else {
            let warm: Vec<f64> = ws.iter().map(|&j| state.beta[j]).collect();
            let res = solver.solve_warm(
                x,
                y,
                &ws,
                lam,
                Some(&warm),
                &inner,
                None,
                &mut state.solver_state,
            );
            iters += res.iters;
            budget_hit = inner.time_budget.is_some() && res.gap > inner.tol_gap;
            beta_full.fill(0.0);
            r.copy_from_slice(y);
            for (k, &j) in ws.iter().enumerate() {
                beta_full[j] = res.beta[k];
                if res.beta[k] != 0.0 {
                    x.col_axpy_into(j, -res.beta[k], &mut r);
                }
            }
        }

        // ---- one shared complement sweep: violators, scores, ‖Xᵀr‖∞ ----
        let (viol, xtr_inf) = kkt_sweep_scored(ctx, &r, lam, &in_ws);
        kkt_passes += 1;
        gap = dual::duality_gap_from_parts(
            y,
            &r,
            crate::linalg::nrm1(&beta_full),
            xtr_inf,
            lam,
        );
        if gap <= opts.tol_gap || budget_hit {
            break;
        }
        if viol.is_empty() {
            // complement is KKT-clean, so the residual gap is pure inner-
            // solve slack: tighten and re-solve the same W (warm-started,
            // so each pass continues the previous descent)
            if inner.tol_gap <= 1e-15 {
                break;
            }
            inner.tol_gap *= 0.25;
            continue;
        }
        // ---- admit the worst violators, doubling the batch per round ----
        expansions += 1;
        for &(j, _) in viol.iter().take(batch) {
            in_ws[j] = true;
        }
        batch = batch.saturating_mul(2);
        ws = (0..p).filter(|&j| in_ws[j]).collect();
    }

    // persist the active warm start: β, accumulated set, momentum. `ws`
    // already contains the previous `state.cols` (seeded above), so
    // assigning it *is* the union.
    state.beta.copy_from_slice(&beta_full);
    state.cols = ws.clone();

    WorkingSetResult {
        beta: beta_full,
        iters,
        gap,
        working_set_size: ws.len(),
        kkt_passes,
        expansions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::solver::cd::CdSolver;

    #[test]
    fn certifies_full_problem_from_empty_seed() {
        // adversarial seed: nothing survives "screening" — the engine must
        // still return a full-problem-certified solution
        let ds = synthetic::synthetic1(30, 240, 12, 0.1, 42);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let lam = 0.3 * ctx.lam_max;
        let opts = SolveOptions::default();
        let seed = vec![false; 240];
        let mut state = WorkingSetState::default();
        let res = solve_working_set(&ctx, lam, &seed, &CdSolver, &opts, &mut state);
        assert!(res.gap <= opts.tol_gap, "gap {}", res.gap);
        assert!(res.kkt_passes >= 2, "empty seed must expand");
        assert!(res.working_set_size < 240, "working set stayed restricted");

        let cols: Vec<usize> = (0..240).collect();
        let tight = SolveOptions { tol_gap: 1e-12, ..Default::default() };
        let full =
            CdSolver.solve(&ds.x, &ds.y, &cols, lam, None, &tight).scatter(&cols, 240);
        for j in 0..240 {
            assert!(
                (res.beta[j] - full[j]).abs() < 2e-4 * (1.0 + full[j].abs()),
                "feature {j}: {} vs {}",
                res.beta[j],
                full[j]
            );
        }
        // no false exclusions: every truly-active coordinate is in W
        for j in 0..240 {
            if full[j].abs() > 1e-6 {
                assert!(state.cols.contains(&j), "active {j} missing from W");
            }
        }
    }

    #[test]
    fn cached_state_certifies_in_one_pass() {
        let ds = synthetic::synthetic1(30, 240, 12, 0.1, 7);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let lam = 0.3 * ctx.lam_max;
        let opts = SolveOptions::default();
        let seed = vec![false; 240];
        let mut state = WorkingSetState::default();
        let first = solve_working_set(&ctx, lam, &seed, &CdSolver, &opts, &mut state);
        let second = solve_working_set(&ctx, lam, &seed, &CdSolver, &opts, &mut state);
        assert!(first.kkt_passes >= 2);
        assert_eq!(second.kkt_passes, 1, "cached W must skip every expansion");
        assert!(second.kkt_passes < first.kkt_passes);
        assert!(second.gap <= opts.tol_gap);
    }

    #[test]
    fn state_reset_on_shape_change() {
        let ds = synthetic::synthetic1(20, 60, 6, 0.1, 9);
        let ctx = ScreenContext::new(&ds.x, &ds.y);
        let lam = 0.4 * ctx.lam_max;
        let mut state = WorkingSetState {
            cols: vec![3, 5],
            beta: vec![1.0; 10], // stale: wrong p
            solver_state: SolverState::None,
        };
        let seed = vec![true; 60];
        let res = solve_working_set(
            &ctx,
            lam,
            &seed,
            &CdSolver,
            &SolveOptions::default(),
            &mut state,
        );
        assert_eq!(state.beta.len(), 60);
        assert!(res.gap <= SolveOptions::default().tol_gap);
    }
}
